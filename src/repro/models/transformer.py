"""Config-driven model assembly: every assigned architecture is a
(pattern × n_periods) stack of PE-style sub-layers over one block zoo.

The layer stack is a ``lax.scan`` over *periods* (one period = one repeat of
``cfg.pattern``), so the HLO holds a single period regardless of depth —
Qwen3's 94 layers compile as one block.  Heterogeneous archs (jamba 1:7,
xLSTM m/s pattern) put the heterogeneity inside the period.

API:
  abstract_params(cfg)                  -> ParamSpec tree
  forward(params, batch, cfg, cache)    -> (logits, aux, new_cache, moe_stats)
  loss(params, batch, cfg)              -> (scalar, metrics incl. moe_drops)
  init_cache(cfg, batch, max_len)       -> decode cache pytree
  prefill / decode_step                 -> serving entry points
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from ..core.noc import NoCConfig
from ..core.partition import constrain
from . import mla as mla_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .attention import AttnConfig, attention, attn_specs, init_cache as attn_init_cache
from .layers import (ParamSpec, cross_entropy, mlp_apply, mlp_specs,
                     rms_norm, stack_specs)


# ---------------------------------------------------------------------------
# sub-config builders
# ---------------------------------------------------------------------------

def _attn_cfg(cfg: ModelConfig) -> AttnConfig:
    return AttnConfig(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                      qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
                      use_rope=cfg.use_rope and cfg.pos_embed == "rope",
                      impl=cfg.attn_impl, bkv=cfg.bkv,
                      logit_softcap=cfg.logit_softcap, seq_shard=cfg.seq_shard_kv,
                      unroll=cfg.analysis_unroll,
                      compute_dtype=cfg.attn_compute_dtype)


def _mla_cfg(cfg: ModelConfig) -> mla_mod.MLAConfig:
    return mla_mod.MLAConfig(cfg.d_model, cfg.n_heads, rope_theta=cfg.rope_theta,
                             impl=cfg.attn_impl, bkv=cfg.bkv,
                             unroll=cfg.analysis_unroll, absorb=cfg.mla_absorb,
                             compute_dtype=cfg.attn_compute_dtype)


def _mamba_cfg(cfg: ModelConfig) -> ssm_mod.MambaConfig:
    return ssm_mod.MambaConfig(cfg.d_model, cfg.mamba_d_state, cfg.mamba_d_conv,
                               cfg.mamba_expand, chunk=cfg.mamba_chunk,
                               unroll=cfg.analysis_unroll)


def _xlstm_cfg(cfg: ModelConfig) -> xlstm_mod.XLSTMConfig:
    return xlstm_mod.XLSTMConfig(cfg.d_model, cfg.n_heads,
                                 proj_factor=cfg.xlstm_proj_factor,
                                 chunk=cfg.xlstm_chunk, unroll=cfg.analysis_unroll)


def _moe_cfg(cfg: ModelConfig) -> moe_mod.MoEConfig:
    # moe_flit_buffer_depth > 0 attaches a NoCConfig: the CONNECT buffer depth
    # becomes the capacity knob and capacity_factor is derived from it
    noc = (NoCConfig(flit_buffer_depth=cfg.moe_flit_buffer_depth)
           if cfg.moe_flit_buffer_depth else None)
    return moe_mod.MoEConfig(cfg.d_model, cfg.n_experts, cfg.top_k, cfg.d_ff_expert,
                             capacity_factor=cfg.capacity_factor, impl=cfg.moe_impl,
                             noc_topology=cfg.moe_topology, act=cfg.act, noc=noc)


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def _sublayer_specs(cfg: ModelConfig, mixer: str, ffn: str, cross: bool, dtype) -> dict:
    d = cfg.d_model
    sp: dict = {"norm1": ParamSpec((d,), ("embed",), dtype, init="ones")}
    if mixer == "attn":
        sp["attn"] = attn_specs(_attn_cfg(cfg), dtype)
    elif mixer == "mla":
        sp["mla"] = mla_mod.mla_specs(_mla_cfg(cfg), dtype)
    elif mixer == "mamba":
        sp["mamba"] = ssm_mod.mamba_specs(_mamba_cfg(cfg), dtype)
    elif mixer == "mlstm":
        sp["mlstm"] = xlstm_mod.mlstm_specs(_xlstm_cfg(cfg), dtype)
    elif mixer == "slstm":
        sp["slstm"] = xlstm_mod.slstm_specs(_xlstm_cfg(cfg), dtype)
    else:
        raise ValueError(mixer)
    if cross:
        sp["norm_x"] = ParamSpec((d,), ("embed",), dtype, init="ones")
        sp["cross"] = attn_specs(_attn_cfg(cfg), dtype)
    if ffn == "mlp":
        sp["norm2"] = ParamSpec((d,), ("embed",), dtype, init="ones")
        sp["mlp"] = mlp_specs(d, cfg.d_ff, dtype, cfg.gated_mlp)
    elif ffn == "moe":
        sp["norm2"] = ParamSpec((d,), ("embed",), dtype, init="ones")
        sp["moe"] = moe_mod.moe_specs(_moe_cfg(cfg), dtype)
    return sp


def _period_specs(cfg: ModelConfig, cross: bool, dtype) -> dict:
    return {str(i): _sublayer_specs(cfg, m, f, cross and m == "attn", dtype)
            for i, (m, f) in enumerate(cfg.pattern)}


def abstract_params(cfg: ModelConfig) -> dict:
    dtype = jnp.float32  # master weights; compute casts per cfg.cdtype
    d, V = cfg.d_model, cfg.vocab_padded
    sp: dict = {
        "embed": ParamSpec((V, d), ("vocab", "embed"), dtype, init="embed", scale=0.02),
        "blocks": stack_specs(_period_specs(cfg, cfg.family == "encdec", dtype),
                              cfg.n_periods),
        "final_norm": ParamSpec((d,), ("embed",), dtype, init="ones"),
    }
    if not cfg.tie_embeddings:
        sp["lm_head"] = ParamSpec((d, V), ("embed", "vocab"), dtype, init="small")
    if cfg.family == "encdec":
        enc_pattern_cfg = cfg.replace(pattern=(("attn", "mlp"),), n_layers=cfg.n_enc_layers)
        sp["enc_blocks"] = stack_specs(_period_specs(enc_pattern_cfg, False, dtype),
                                       cfg.n_enc_layers)
        sp["enc_norm"] = ParamSpec((d,), ("embed",), dtype, init="ones")
        sp["frontend"] = ParamSpec((cfg.d_frontend, d), (None, "embed"), dtype)
    if cfg.family == "vlm":
        sp["frontend"] = ParamSpec((cfg.d_frontend, d), (None, "embed"), dtype)
    return sp


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _sublayer_cache(cfg: ModelConfig, mixer: str, batch: int, max_len: int):
    if mixer == "attn":
        return attn_init_cache(_attn_cfg(cfg), batch, max_len, cfg.cdtype)
    if mixer == "mla":
        return mla_mod.init_mla_cache(_mla_cfg(cfg), batch, max_len, cfg.cdtype)
    if mixer == "mamba":
        return ssm_mod.init_mamba_cache(_mamba_cfg(cfg), batch, jnp.float32)
    if mixer == "mlstm":
        return xlstm_mod.init_mlstm_cache(_xlstm_cfg(cfg), batch, jnp.float32)
    if mixer == "slstm":
        return xlstm_mod.init_slstm_cache(_xlstm_cfg(cfg), batch, jnp.float32)
    raise ValueError(mixer)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    per = {str(i): _sublayer_cache(cfg, m, batch, max_len)
           for i, (m, _) in enumerate(cfg.pattern)}
    P = cfg.n_periods
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (P,) + x.shape) + jnp.zeros((), x.dtype), per)
    return {"blocks": stacked, "pos": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _norm(x, gamma, cfg: ModelConfig):
    return rms_norm(x, gamma.astype(x.dtype), cfg.norm_eps)


def _apply_sublayer(p, x, cfg: ModelConfig, mixer: str, ffn: str, *,
                    positions, cache, enc_out, causal):
    aux = jnp.zeros((), jnp.float32)
    moe = (jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))  # (drops, peak)
    h = _norm(x, p["norm1"], cfg)
    if mixer == "attn":
        o, new_cache = attention(p["attn"], h, _attn_cfg(cfg), positions=positions,
                                 cache=cache, causal=causal)
    elif mixer == "mla":
        o, new_cache = mla_mod.mla_apply(p["mla"], h, _mla_cfg(cfg),
                                         positions=positions, cache=cache)
    elif mixer == "mamba":
        o, new_cache = ssm_mod.mamba_apply(p["mamba"], h, _mamba_cfg(cfg), cache)
    elif mixer == "mlstm":
        o, new_cache = xlstm_mod.mlstm_apply(p["mlstm"], h, _xlstm_cfg(cfg), cache)
    elif mixer == "slstm":
        o, new_cache = xlstm_mod.slstm_apply(p["slstm"], h, _xlstm_cfg(cfg), cache)
    else:
        raise ValueError(mixer)
    x = x + o
    if enc_out is not None and "cross" in p:
        hx = _norm(x, p["norm_x"], cfg)
        kv_k = jnp.einsum("btd,dhk->bhtk", enc_out, p["cross"]["wk"].astype(x.dtype))
        kv_v = jnp.einsum("btd,dhk->bhtk", enc_out, p["cross"]["wv"].astype(x.dtype))
        o, _ = attention(p["cross"], hx, _attn_cfg(cfg), positions=positions,
                         kv_override=(kv_k, kv_v), causal=False)
        x = x + o
    if ffn == "mlp":
        h = _norm(x, p["norm2"], cfg)
        x = x + mlp_apply(p["mlp"], h, act="silu" if cfg.act == "silu" else "gelu")
    elif ffn == "moe":
        h = _norm(x, p["norm2"], cfg)
        o, aux, st = moe_mod.moe_apply(p["moe"], h, _moe_cfg(cfg))
        moe = (jnp.asarray(st.drops, jnp.int32),
               jnp.asarray(st.peak_occupancy, jnp.int32))
        x = x + o
    return x, new_cache, aux, moe


def _run_stack(blocks, x, cfg: ModelConfig, *, pattern, positions, cache_blocks,
               enc_out, causal):
    """scan over periods; xs = (stacked period params, stacked period caches).

    MoE dispatch stats ride the carry: drops sum over layers, peak-occupancy
    maxes (the hottest (src, dst) buffer anywhere in the stack)."""

    def period_fn(carry, xs):
        x, aux, drops, peak = carry
        if cache_blocks is not None:
            pp, pc = xs
        else:
            pp, pc = xs, None
        new_pc = {}
        for i, (mixer, ffn) in enumerate(pattern):
            sub_cache = pc[str(i)] if pc is not None else None
            x, nc, a, (dr, pk) = _apply_sublayer(pp[str(i)], x, cfg, mixer, ffn,
                                                 positions=positions, cache=sub_cache,
                                                 enc_out=enc_out, causal=causal)
            new_pc[str(i)] = nc if nc is not None else ()
            aux = aux + a
            drops = drops + dr
            peak = jnp.maximum(peak, pk)
        x = constrain(x, ("batch", "seq", "embed"))
        return (x, aux, drops, peak), (new_pc if pc is not None else 0)

    body = jax.checkpoint(period_fn) if cfg.remat else period_fn
    xs = (blocks, cache_blocks) if cache_blocks is not None else blocks
    carry0 = (x, jnp.zeros((), jnp.float32),
              jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
    (x, aux, drops, peak), new_caches = lax.scan(
        body, carry0, xs, unroll=cfg.n_periods if cfg.analysis_unroll else 1)
    moe_stats = {"moe_drops": drops, "moe_peak_occupancy": peak}
    return x, aux, (new_caches if cache_blocks is not None else None), moe_stats


def _embed_tokens(params, tokens, cfg: ModelConfig):
    e = params["embed"].astype(cfg.cdtype)[tokens]
    return e * jnp.asarray(cfg.embed_scale, cfg.cdtype)


def _sinusoidal(positions, d, dtype):
    half = d // 2
    freqs = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def encode(params, frames, cfg: ModelConfig):
    """Audio encoder: precomputed frame embeddings (stubbed conv frontend)
    -> frontend proj -> sinusoidal pos -> bidirectional stack."""
    x = frames.astype(cfg.cdtype) @ params["frontend"].astype(cfg.cdtype)
    pos = jnp.arange(x.shape[1])[None, :]
    x = x + _sinusoidal(pos, cfg.d_model, x.dtype)
    x, _, _, _ = _run_stack(params["enc_blocks"], x, cfg, pattern=(("attn", "mlp"),),
                            positions=jnp.broadcast_to(pos, x.shape[:2]),
                            cache_blocks=None, enc_out=None, causal=False)
    return _norm(x, params["enc_norm"], cfg)


def forward(params: dict, batch: dict, cfg: ModelConfig,
            cache: Optional[dict] = None
            ) -> tuple[jax.Array, jax.Array, Optional[dict], dict]:
    """-> (logits (B,S,V), aux_loss, new_cache, moe_stats).

    ``moe_stats``: {"moe_drops", "moe_peak_occupancy"} — capacity-dropped
    tokens summed over MoE layers and the hottest per-(src, dst) dispatch
    buffer, straight from `moe.MoEDispatchStats` (zeros for dense archs)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    pos0 = cache["pos"] if cache is not None else 0
    positions = pos0 + jnp.broadcast_to(jnp.arange(S)[None, :], (B, S)).astype(jnp.int32)

    x = _embed_tokens(params, tokens, cfg)
    if cfg.pos_embed == "sinusoidal":
        x = x + _sinusoidal(positions, cfg.d_model, x.dtype)

    enc_out = None
    if cfg.family == "encdec":
        enc_out = (cache.get("enc_out") if cache is not None else None)
        if enc_out is None:
            enc_out = encode(params, batch["frames"], cfg)
    if cfg.family == "vlm" and "patches" in batch:
        pre = batch["patches"].astype(cfg.cdtype) @ params["frontend"].astype(cfg.cdtype)
        x = jnp.concatenate([pre, x], axis=1)
        S = x.shape[1]
        positions = pos0 + jnp.broadcast_to(jnp.arange(S)[None, :], (B, S)).astype(jnp.int32)

    x = constrain(x, ("batch", "seq", "embed"))
    cache_blocks = cache["blocks"] if cache is not None else None
    x, aux, new_blocks, moe_stats = _run_stack(
        params["blocks"], x, cfg, pattern=cfg.pattern, positions=positions,
        cache_blocks=cache_blocks, enc_out=enc_out, causal=True)
    x = _norm(x, params["final_norm"], cfg)
    if cfg.family == "vlm" and "patches" in batch:
        x = x[:, -tokens.shape[1]:]  # logits only for text positions
    head = (params["embed"].astype(x.dtype).T if cfg.tie_embeddings
            else params["lm_head"].astype(x.dtype))
    logits = constrain(x @ head, ("batch", "seq", "vocab"))
    if cfg.vocab_padded != cfg.vocab:
        # mask padded classes in place (sharded-dim slice would re-layout)
        vid = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(vid < cfg.vocab, logits, jnp.asarray(-1e30, logits.dtype))
    new_cache = None
    if cache is not None:
        new_cache = {"blocks": new_blocks, "pos": pos0 + S}
        if cfg.family == "encdec":
            new_cache["enc_out"] = enc_out
    return logits, aux, new_cache, moe_stats


def loss(params: dict, batch: dict, cfg: ModelConfig):
    logits, aux, _, moe_stats = forward(params, batch, cfg)
    nll = cross_entropy(logits, batch["labels"])
    total = nll + cfg.aux_weight * aux
    # f32 so downstream metric pmean/averaging is well-defined
    mets = {k: v.astype(jnp.float32) for k, v in moe_stats.items()}
    return total, {"nll": nll, "aux": aux, **mets}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def prefill(params: dict, batch: dict, cfg: ModelConfig, cache: dict):
    logits, _, cache, _ = forward(params, batch, cfg, cache)
    return logits[:, -1:], cache


def decode_step(params: dict, batch: dict, cfg: ModelConfig, cache: dict):
    """batch["tokens"]: (B, 1) — one new token against the cache."""
    logits, _, cache, _ = forward(params, batch, cfg, cache)
    return logits[:, -1], cache
