"""Mixture-of-Experts layer — the paper's packet-switched NoC, verbatim.

Tokens are packets; the router's top-k gate writes the destination PE
(expert) into each packet header; dispatch/combine are the Data
Distributor / Data Collector wrappers; per-(src, expert) buffer capacity is
the CONNECT flit-buffer-depth analog (tokens beyond capacity are dropped,
exactly like a bounded FIFO back-pressuring).

Two engines (both first-class, selectable per config):

* ``gather`` — expert parallelism over model-axis-replicated activations:
  every model rank gathers the tokens addressed to its local experts
  (capacity-bounded), computes, scatter-adds, and a single psum over 'model'
  combines.  Comm = one d-sized all-reduce; no all-to-all.  Robust default
  for giant pjit graphs.

* ``noc`` — the paper-faithful packet route: activations arrive
  sequence-sharded over 'model'; per-destination-rank packet cubes move
  through the topology's *compiled route program*
  (`core.routing.compile_routes` → `run_route_program`, linearized over the
  'model' axis: fat-tree → one fused all_to_all; ring/mesh/torus → per-hop
  ppermute rounds), experts compute, and the return path runs the same
  program again.  This is phase-1+phase-2 of the paper applied to an LM
  layer; `core.routing.route_program_stats` yields the exact flit/round/
  link-byte counters per invocation (:class:`MoEDispatchStats`).

Capacity semantics are UNIFIED across engines (`dispatch_capacity`): both
budget token slots per (source shard, expert) dispatch FIFO, so the same
config drops the same tokens whichever engine runs (property-tested).  With
an attached :class:`~repro.core.noc.NoCConfig`, its ``flit_buffer_depth`` IS
the capacity knob — the effective ``capacity_factor`` is derived from it,
not configured separately.

Packet framing on the noc engine is *static*, like the NoC executor's
compiled flit programs: the (expert, slot) position inside the per-(src,dst)
cube encodes the destination expert, so no header bytes ride the links —
the same compile-time-contract framing `core.noc` uses for app graphs.

Both engines implement the same math (property-tested against ``dense_ref``).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import get_abstract_mesh, shard_map
from ..core.noc import NoCConfig
from .layers import ParamSpec


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25
    impl: str = "gather"            # gather | noc | dense
    noc_topology: str = "fattree"   # fattree | ring | mesh2d | torus2d
    act: str = "silu"
    # NoC dispatch options: when set, flit_buffer_depth becomes the capacity
    # knob (capacity_factor is then *derived* — see dispatch_capacity)
    noc: Optional[NoCConfig] = None


@dataclasses.dataclass
class MoEDispatchStats:
    """Per-invocation dispatch accounting, returned by :func:`moe_apply`.

    ``drops`` / ``peak_occupancy`` are data-dependent (traced under jit);
    everything else is static, derived from shapes and the compiled route
    program.  For ``engine="noc"`` the flit/round/link-byte counters are
    exactly ``2 ×`` :func:`~repro.core.routing.route_program_stats` of the
    dispatched token cube (outbound trip + return trip) — tested.
    Counters are per model-axis NoC invocation (data-parallel replicas run
    their own concurrent dispatch; rounds are physical, counted once).
    """

    engine: str                     # engine that actually ran
    topology: Optional[str]         # noc engine: the routed topology
    fallback: Optional[str]         # reason a requested engine was not used
    capacity: int                   # per-(src, expert) FIFO depth, token slots
    capacity_factor: float          # effective (possibly derived) factor
    flits: int                      # framed flits on the links (out + back)
    rounds: int                     # ppermute rounds (out + back)
    link_bytes: int                 # bytes crossing topology links
    drops: Any = 0                  # tokens dropped by capacity (traced)
    peak_occupancy: Any = 0         # max tokens demanded of one (src,dst) buffer

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def publish(self, registry=None) -> None:
        """Publish into the telemetry metrics registry under the canonical
        ``noc.moe.*`` names (`repro.telemetry.MOE_METRIC_NAMES`) — the same
        names the train loop's step metrics land on, so transformer metrics
        and NoC dispatch stats share one schema.  No-op when metrics are off
        or fields still hold traced values (publish host-side)."""
        if registry is None:
            from ..telemetry.metrics import get_registry
            registry = get_registry()
        if registry is not None:
            registry.record_moe_stats(self)


def moe_specs(c: MoEConfig, dtype=jnp.float32) -> dict:
    E, d, f = c.n_experts, c.d_model, c.d_ff
    return {
        "router": ParamSpec((d, E), ("embed", None), dtype, init="small"),
        "gate": ParamSpec((E, d, f), ("experts", "embed", "expert_mlp"), dtype),
        "up": ParamSpec((E, d, f), ("experts", "embed", "expert_mlp"), dtype),
        "down": ParamSpec((E, f, d), ("experts", "expert_mlp", "embed"), dtype),
    }


def _act(x, kind):
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x, approximate=True)


# ---------------------------------------------------------------------------
# capacity — ONE formula for both engines
# ---------------------------------------------------------------------------

def dispatch_capacity(tokens_per_src: int, c: MoEConfig) -> int:
    """Per-(source shard, expert) dispatch-FIFO depth in token slots.

    The single capacity budget both engines enforce (gather == noc parity:
    the same tokens are dropped whichever engine runs).  With an attached
    NoCConfig the CONNECT ``flit_buffer_depth`` IS the knob — each
    (src, expert) FIFO holds that many token slots, exactly (depth 1 must be
    expressible for the drops-vs-depth sweep) and the effective
    capacity_factor falls out (:func:`effective_capacity_factor`).  Without
    one, the classic ``tokens·top_k·capacity_factor / n_experts`` formula
    applies with the legacy floor of 8 slots, so small-T decode-shaped
    dispatch stays drop-free as it always was.  Clamped to
    [1, tokens_per_src·top_k]."""
    if c.noc is not None:
        cap = c.noc.flit_buffer_depth
    else:
        cap = max(8, int(tokens_per_src * c.top_k * c.capacity_factor / c.n_experts))
    return max(1, min(cap, tokens_per_src * c.top_k))


def effective_capacity_factor(tokens_per_src: int, c: MoEConfig) -> float:
    """The capacity_factor implied by :func:`dispatch_capacity` — the derived
    quantity the stats report (never an independent second knob)."""
    cap = dispatch_capacity(tokens_per_src, c)
    return cap * c.n_experts / (tokens_per_src * c.top_k)


def _dispatch_slots(flat_dst, blk_of_pkt, experts, n_blocks: int, cap: int):
    """First-``cap`` (arrival order) packet slots per (expert, source block).

    flat_dst: (P,) destination expert of each packet; blk_of_pkt: (P,) source
    block; experts: (E',) expert ids to dispatch (may be traced).  Returns
    (slots, valid) of shape (E', n_blocks, cap)."""
    npkt = flat_dst.shape[0]
    arrival = -jnp.arange(npkt, dtype=jnp.float32)

    def pick(e, blk):
        mine = (flat_dst == e) & (blk_of_pkt == blk)
        score = jnp.where(mine, arrival, -jnp.inf)
        _, slots = lax.top_k(score, cap)
        return slots, mine[slots]

    ne = experts.shape[0]
    ee = jnp.repeat(experts, n_blocks)
    bb = jnp.tile(jnp.arange(n_blocks), ne)
    slots, valid = jax.vmap(pick)(ee, bb)
    return slots.reshape(ne, n_blocks, cap), valid.reshape(ne, n_blocks, cap)


def _dispatch_counts(flat_dst, blk_of_pkt, n_experts: int, n_blocks: int):
    """Demanded tokens per (expert, source block) — pre-capacity load."""
    return jnp.zeros((n_experts, n_blocks), jnp.int32).at[
        flat_dst, blk_of_pkt].add(1)


def _drops_and_peak(counts, cap: int, n_ranks: int):
    """(Σ_e relu(load_e - cap), max per-(src-block, dst-rank) demand)."""
    epr = counts.shape[0] // n_ranks
    drops = jnp.sum(jnp.maximum(counts - cap, 0))
    per_pair = counts.reshape(n_ranks, epr, -1).sum(axis=1)   # (dst_rank, blk)
    return drops, per_pair.max()


def _router(x_flat, wr, c: MoEConfig):
    """x_flat (T, d) -> (weights (T,k), idx (T,k), aux_loss, (me, ce)).

    The router dot keeps bf16 OPERANDS with f32 accumulation: casting the
    operands to f32 would make the backward emit an f32 (T, d) cotangent that
    poisons the whole residual-stream backward into f32 (2× HBM traffic on
    every layer — found via the roofline anchor dump, §Perf C2)."""
    logits = jax.lax.dot(x_flat, wr.astype(x_flat.dtype),
                         preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, c.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss terms (reduce across shards BEFORE the
    # product — mean-of-products != product-of-means)
    E = c.n_experts
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return w.astype(x_flat.dtype), idx, aux, (me, ce)


def dense_ref(params, x, c: MoEConfig):
    """O(E·T·d·f) reference: every token through every expert, gate-combined.
    The oracle for both engines (small shapes only)."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    w, idx, aux, _mece = _router(xf, params["router"], c)
    gate_full = jnp.zeros((xf.shape[0], c.n_experts), x.dtype)
    gate_full = jax.vmap(lambda g, i, ww: g.at[i].set(ww))(gate_full, idx, w)
    h = jnp.einsum("td,edf->tef", xf, params["gate"].astype(x.dtype))
    u = jnp.einsum("td,edf->tef", xf, params["up"].astype(x.dtype))
    y = jnp.einsum("tef,efd->ted", _act(h, c.act) * u, params["down"].astype(x.dtype))
    out = jnp.einsum("ted,te->td", y, gate_full)
    return out.reshape(B, S, d), aux


def _expert_ffn(xe, wg, wu, wd, act):
    """xe (E_loc, C, d) through stacked local experts."""
    h = jnp.einsum("ecd,edf->ecf", xe, wg)
    u = jnp.einsum("ecd,edf->ecf", xe, wu)
    return jnp.einsum("ecf,efd->ecd", _act(h, act) * u, wd)


# ---------------------------------------------------------------------------
# engine 1: gather (EP over replicated activations)
# ---------------------------------------------------------------------------

def _gather_local(x_flat, wr, wg, wu, wd, c: MoEConfig, n_ranks: int, axis: str,
                  blk_of, n_blocks: int):
    """blk_of: (T,) source block of each token (== the noc engine's source
    rank when the sequence divides), so capacity is enforced per
    (source block, expert) — identical drop sets to the noc engine."""
    T, d = x_flat.shape
    rank = lax.axis_index(axis)
    epr = c.n_experts // n_ranks
    cap = dispatch_capacity(T // n_blocks, c)
    w, idx, _, (me, ce) = _router(x_flat, wr, c)

    # packet headers: (T*k,) destination expert + combine weight
    flat_dst = idx.reshape(-1)
    flat_w = w.reshape(-1)
    tok_of = jnp.repeat(jnp.arange(T), c.top_k)
    blk_of_pkt = jnp.repeat(blk_of, c.top_k)

    local_e = rank * epr + jnp.arange(epr)
    slots, valid = _dispatch_slots(flat_dst, blk_of_pkt, local_e, n_blocks, cap)
    slots = slots.reshape(epr, -1)                          # (epr, n_blocks*cap)
    valid = valid.reshape(epr, -1)
    toks = tok_of[slots]
    xe = x_flat[toks] * valid[..., None].astype(x_flat.dtype)
    ye = _expert_ffn(xe, wg, wu, wd, c.act)                 # (epr, B*cap, d)
    comb = (flat_w[slots] * valid.astype(flat_w.dtype))[..., None]
    out = jnp.zeros_like(x_flat)
    out = out.at[toks.reshape(-1)].add((ye * comb).reshape(-1, d))
    out = lax.psum(out, axis)                               # combine expert ranks
    counts = _dispatch_counts(flat_dst, blk_of_pkt, c.n_experts, n_blocks)
    drops, peak = _drops_and_peak(counts, cap, n_ranks)     # full-layer (replicated)
    return out, (me, ce), (drops, peak)


# ---------------------------------------------------------------------------
# engine 2: noc (paper packet switching over the compiled route program)
# ---------------------------------------------------------------------------

def _noc_local(x_flat, wr, wg, wu, wd, c: MoEConfig, n_ranks: int, axis: str,
               prog, cap: int):
    """x_flat: (T_loc, d) — tokens sequence-sharded over ``axis``.

    Pack per-destination-rank token cubes (static (expert, slot) framing),
    move them out and back with the compiled :class:`RouteProgram`
    (`run_route_program` linearized over ``axis``), compute, combine.
    """
    from ..core.routing import run_route_program

    T, d = x_flat.shape
    E = c.n_experts
    epr = E // n_ranks
    w, idx, _, (me, ce) = _router(x_flat, wr, c)

    flat_dst = idx.reshape(-1)                               # (T*k,) expert id
    flat_w = w.reshape(-1)
    tok_of = jnp.repeat(jnp.arange(T), c.top_k)
    blk0 = jnp.zeros_like(flat_dst)                          # one local source block

    slots, valid = _dispatch_slots(flat_dst, blk0, jnp.arange(E), 1, cap)
    slots, valid = slots[:, 0], valid[:, 0]                  # (E, cap)
    toks = tok_of[slots]
    payload = x_flat[toks] * valid[..., None].astype(x_flat.dtype)   # (E, cap, d)

    # --- outbound: Data Distributor -> compiled route program -> Collector.
    # payload row e = (dst_rank e//epr, local expert e%epr): rank-major, so the
    # (n_ranks, epr*cap, d) cube is destination-indexed as the program expects.
    cube = payload.reshape(n_ranks, epr * cap, d)
    rx = run_route_program(cube, prog, axis_name=axis)       # (src_rank, epr*cap, d)

    # --- local expert compute; slot position IS the header (static framing)
    xe = rx.reshape(n_ranks, epr, cap, d)
    xe = jnp.moveaxis(xe, 1, 0).reshape(epr, n_ranks * cap, d)
    ye = _expert_ffn(xe, wg, wu, wd, c.act)                  # (epr, R*cap, d)

    # --- return trip: the same program, cube destination-indexed by src rank
    ycube = jnp.moveaxis(ye.reshape(epr, n_ranks, cap, d), 1, 0)
    back = run_route_program(ycube.reshape(n_ranks, epr * cap, d), prog,
                             axis_name=axis)                 # (exp_rank, epr*cap, d)
    back = back.reshape(E, cap, d)                           # slot-aligned with payload
    contrib = back * (flat_w[slots] * valid.astype(flat_w.dtype))[..., None]
    out = jnp.zeros_like(x_flat)
    out = out.at[toks.reshape(-1)].add(contrib.reshape(-1, d))
    counts = _dispatch_counts(flat_dst, blk0, E, 1)
    drops, peak = _drops_and_peak(counts, cap, n_ranks)      # this shard's share
    return out, (me, ce), (drops, peak)


# ---------------------------------------------------------------------------
# public layer
# ---------------------------------------------------------------------------

def _static_stats(engine: str, c: MoEConfig, *, fallback=None, topology=None,
                  capacity=0, tokens_per_src=0, flits=0, rounds=0,
                  link_bytes=0, drops=0, peak=0) -> MoEDispatchStats:
    cf = (effective_capacity_factor(tokens_per_src, c) if tokens_per_src
          else c.capacity_factor)
    return MoEDispatchStats(engine=engine, topology=topology, fallback=fallback,
                            capacity=capacity, capacity_factor=cf, flits=flits,
                            rounds=rounds, link_bytes=link_bytes, drops=drops,
                            peak_occupancy=peak)


def moe_apply(params: dict, x: jax.Array, c: MoEConfig
              ) -> tuple[jax.Array, jax.Array, MoEDispatchStats]:
    """x: (B, S, d) -> (out, aux_loss, MoEDispatchStats).

    Engine per ``c.impl``.  Every fallback away from the requested engine is
    recorded in ``stats.fallback``; the silent-perf-cliff ones (expert count
    not divisible across ranks, decode-shaped inputs demoting ``noc``) also
    emit a ``UserWarning``.  The expected single-host no-mesh path records a
    reason without warning."""
    if c.impl == "dense":
        out, aux = dense_ref(params, x, c)
        return out, aux, _static_stats("dense", c)

    mesh = get_abstract_mesh()
    if mesh is None or "model" not in (mesh.axis_names or ()):
        # no mesh context (unit tests / single host): run the oracle
        out, aux = dense_ref(params, x, c)
        return out, aux, _static_stats(
            "dense", c, fallback="no mesh context ('model' axis absent)")
    n_ranks = mesh.shape["model"]
    if c.n_experts % n_ranks:
        reason = (f"n_experts={c.n_experts} not divisible by model ranks="
                  f"{n_ranks}: dense_ref fallback, O(E*T*d*f) per token")
        warnings.warn(f"moe_apply: {reason}", stacklevel=2)
        out, aux = dense_ref(params, x, c)
        return out, aux, _static_stats("dense", c, fallback=reason)

    B, S, d = x.shape
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_batch = 1
    for a in batch_axes:
        n_batch *= mesh.shape[a]
    if B % max(n_batch, 1):
        batch_axes = ()          # tiny-batch decode: replicate over data axes
        n_batch = 1
    bspec = batch_axes if batch_axes else None
    wspec = P("model", None, None)
    all_axes = batch_axes + ("model",)
    impl, fallback = c.impl, None
    if impl == "noc" and (S < n_ranks or S % n_ranks):
        fallback = (f"impl='noc' needs seq len {S} divisible by model ranks="
                    f"{n_ranks} (decode-shaped input): using 'gather'")
        warnings.warn(f"moe_apply: {fallback}", stacklevel=2)
        impl = "gather"

    B_loc = B // n_batch

    def _aux_of(me, ce, axes):
        if axes:
            me = lax.pmean(me, axes)
            ce = lax.pmean(ce, axes)
        return c.n_experts * jnp.sum(me * ce)

    if impl == "gather":
        T = B_loc * S
        # source blocks == the noc engine's sequence shards when S divides,
        # so both engines enforce the SAME per-(src, expert) capacity
        n_blocks = n_ranks if S % n_ranks == 0 else 1
        blk_of = (jnp.arange(T) % S) // (S // n_blocks)

        def fn(xl, wr, wg, wu, wd):
            out, (me, ce), (drops, peak) = _gather_local(
                xl.reshape(T, d), wr, wg, wu, wd, c, n_ranks, "model",
                blk_of, n_blocks)
            if batch_axes:       # drops replicated over 'model'; sum replicas
                drops = lax.psum(drops, batch_axes)
                peak = lax.pmax(peak, batch_axes)
            return (out.reshape(xl.shape), _aux_of(me, ce, batch_axes),
                    drops, peak)
        sm = shard_map(
            fn, mesh=mesh,
            in_specs=(P(bspec, None, None), P(), wspec, wspec, wspec),
            out_specs=(P(bspec, None, None), P(), P(), P()),
            check_vma=False)
        out, aux, drops, peak = sm(
            x, params["router"].astype(x.dtype), params["gate"].astype(x.dtype),
            params["up"].astype(x.dtype), params["down"].astype(x.dtype))
        stats = _static_stats("gather", c, fallback=fallback,
                              capacity=dispatch_capacity(T // n_blocks, c),
                              tokens_per_src=T // n_blocks,
                              drops=drops, peak=peak)
        return out, aux.reshape(()), stats

    # impl == "noc": compile the topology's route program once per call site
    from ..core.routing import compile_routes, route_program_stats
    from ..core.topology import make_topology

    topo = make_topology(c.noc_topology, n_ranks)
    prog = compile_routes(topo)
    ncfg = c.noc or NoCConfig()
    T_loc = B_loc * (S // n_ranks)
    cap = dispatch_capacity(T_loc, c)
    epr = c.n_experts // n_ranks
    msg_nbytes = epr * cap * d * x.dtype.itemsize   # one (src,dst) token cube
    sstats = route_program_stats(prog, n_ranks * n_ranks * msg_nbytes)

    def fn(xl, wr, wg, wu, wd):
        xl2 = xl.reshape(-1, d)
        out, (me, ce), (drops, peak) = _noc_local(
            xl2, wr, wg, wu, wd, c, n_ranks, "model", prog, cap)
        return (out.reshape(xl.shape), _aux_of(me, ce, all_axes),
                lax.psum(drops, all_axes), lax.pmax(peak, all_axes))
    sm = shard_map(
        fn, mesh=mesh,
        in_specs=(P(bspec, "model", None), P(), wspec, wspec, wspec),
        out_specs=(P(bspec, "model", None), P(), P(), P()),
        check_vma=False)
    out, aux, drops, peak = sm(
        x, params["router"].astype(x.dtype), params["gate"].astype(x.dtype),
        params["up"].astype(x.dtype), params["down"].astype(x.dtype))
    stats = _static_stats(
        "noc", c, fallback=fallback, topology=c.noc_topology, capacity=cap,
        tokens_per_src=T_loc,
        # out + back trips of the same program; flits frame all n^2 buffers
        flits=2 * n_ranks * n_ranks * ncfg.flits_for(msg_nbytes),
        rounds=2 * sstats.rounds, link_bytes=2 * sstats.link_bytes,
        drops=drops, peak=peak)
    return out, aux.reshape(()), stats
