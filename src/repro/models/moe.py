"""Mixture-of-Experts layer — the paper's packet-switched NoC, verbatim.

Tokens are packets; the router's top-k gate writes the destination PE
(expert) into each packet header; dispatch/combine are the Data
Distributor / Data Collector wrappers; per-(src,dst) buffer capacity is the
CONNECT flit-buffer-depth analog (tokens beyond capacity are dropped, exactly
like a bounded FIFO back-pressuring).

Two engines (both first-class, selectable per config):

* ``gather`` — expert parallelism over model-axis-replicated activations:
  every model rank gathers the tokens addressed to its local experts
  (capacity-bounded), computes, scatter-adds, and a single psum over 'model'
  combines.  Comm = one d-sized all-reduce; no all-to-all.  Robust default
  for giant pjit graphs.

* ``noc`` — the paper-faithful packet route: activations arrive
  sequence-sharded over 'model'; per-destination-rank packet buffers go
  through the *topology routing schedule* (`core.routing`: fat-tree → one
  fused all_to_all; ring/torus → ppermute rounds), experts compute, and the
  return path reuses the same schedule.  This is phase-1+phase-2 of the
  paper applied to an LM layer.

Both engines implement the same math (property-tested against ``dense_ref``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import get_abstract_mesh, shard_map
from ..core.partition import constrain
from .layers import ParamSpec


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25
    impl: str = "gather"            # gather | noc | dense
    noc_topology: str = "fattree"   # fattree | ring  (routing schedule for impl=noc)
    act: str = "silu"


def moe_specs(c: MoEConfig, dtype=jnp.float32) -> dict:
    E, d, f = c.n_experts, c.d_model, c.d_ff
    return {
        "router": ParamSpec((d, E), ("embed", None), dtype, init="small"),
        "gate": ParamSpec((E, d, f), ("experts", "embed", "expert_mlp"), dtype),
        "up": ParamSpec((E, d, f), ("experts", "embed", "expert_mlp"), dtype),
        "down": ParamSpec((E, f, d), ("experts", "expert_mlp", "embed"), dtype),
    }


def _act(x, kind):
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x, approximate=True)


def _router(x_flat, wr, c: MoEConfig):
    """x_flat (T, d) -> (weights (T,k), idx (T,k), aux_loss, (me, ce)).

    The router dot keeps bf16 OPERANDS with f32 accumulation: casting the
    operands to f32 would make the backward emit an f32 (T, d) cotangent that
    poisons the whole residual-stream backward into f32 (2× HBM traffic on
    every layer — found via the roofline anchor dump, §Perf C2)."""
    logits = jax.lax.dot(x_flat, wr.astype(x_flat.dtype),
                         preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, c.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss terms (reduce across shards BEFORE the
    # product — mean-of-products != product-of-means)
    E = c.n_experts
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return w.astype(x_flat.dtype), idx, aux, (me, ce)


def dense_ref(params, x, c: MoEConfig):
    """O(E·T·d·f) reference: every token through every expert, gate-combined.
    The oracle for both engines (small shapes only)."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    w, idx, aux, _mece = _router(xf, params["router"], c)
    gate_full = jnp.zeros((xf.shape[0], c.n_experts), x.dtype)
    gate_full = jax.vmap(lambda g, i, ww: g.at[i].set(ww))(gate_full, idx, w)
    h = jnp.einsum("td,edf->tef", xf, params["gate"].astype(x.dtype))
    u = jnp.einsum("td,edf->tef", xf, params["up"].astype(x.dtype))
    y = jnp.einsum("tef,efd->ted", _act(h, c.act) * u, params["down"].astype(x.dtype))
    out = jnp.einsum("ted,te->td", y, gate_full)
    return out.reshape(B, S, d), aux


def _expert_ffn(xe, wg, wu, wd, act):
    """xe (E_loc, C, d) through stacked local experts."""
    h = jnp.einsum("ecd,edf->ecf", xe, wg)
    u = jnp.einsum("ecd,edf->ecf", xe, wu)
    return jnp.einsum("ecf,efd->ecd", _act(h, act) * u, wd)


# ---------------------------------------------------------------------------
# engine 1: gather (EP over replicated activations)
# ---------------------------------------------------------------------------

def _gather_local(x_flat, wr, wg, wu, wd, c: MoEConfig, n_ranks: int, axis: str):
    T, d = x_flat.shape
    rank = lax.axis_index(axis)
    epr = c.n_experts // n_ranks
    cap = min(max(8, int(T * c.top_k * c.capacity_factor / c.n_experts)),
              T * c.top_k)
    w, idx, _, (me, ce) = _router(x_flat, wr, c)

    # packet headers: (T*k,) destination expert + combine weight
    flat_dst = idx.reshape(-1)
    flat_w = w.reshape(-1)
    tok_of = jnp.repeat(jnp.arange(T), c.top_k)

    def pick(e):
        """first-`cap` (arrival order) packet slots addressed to expert e."""
        mine = flat_dst == e
        score = jnp.where(mine, -jnp.arange(T * c.top_k, dtype=jnp.float32), -jnp.inf)
        _, slots = lax.top_k(score, cap)
        valid = mine[slots]
        return slots, valid

    local_e = rank * epr + jnp.arange(epr)
    slots, valid = jax.vmap(pick)(local_e)                  # (epr, cap)
    toks = tok_of[slots]                                    # (epr, cap)
    xe = x_flat[toks] * valid[..., None].astype(x_flat.dtype)
    ye = _expert_ffn(xe, wg, wu, wd, c.act)                 # (epr, cap, d)
    comb = (flat_w[slots] * valid.astype(flat_w.dtype))[..., None]
    out = jnp.zeros_like(x_flat)
    out = out.at[toks.reshape(-1)].add((ye * comb).reshape(-1, d))
    out = lax.psum(out, axis)                               # combine expert ranks
    return out, (me, ce)


# ---------------------------------------------------------------------------
# engine 2: noc (paper packet switching over the topology schedule)
# ---------------------------------------------------------------------------

def _noc_local(x_flat, wr, wg, wu, wd, c: MoEConfig, n_ranks: int, axis: str):
    """x_flat: (T_loc, d) — tokens sequence-sharded over `axis`.

    Route token packets to expert ranks with the topology schedule, compute,
    route back with the same schedule, combine.
    """
    from ..core.routing import crossbar_all_to_all, ring_all_to_all_unidir

    a2a = (functools.partial(ring_all_to_all_unidir, axis_name=axis)
           if c.noc_topology == "ring" else
           functools.partial(crossbar_all_to_all, axis_name=axis))

    T, d = x_flat.shape
    rank = lax.axis_index(axis)
    epr = c.n_experts // n_ranks
    # per-(src,dst-rank) packet buffer capacity — the flit-buffer-depth analog
    cap = min(max(8, int(T * c.top_k * c.capacity_factor / n_ranks)), T * c.top_k)
    w, idx, _, (me, ce) = _router(x_flat, wr, c)

    flat_dst_rank = (idx // epr).reshape(-1)                # (T*k,)
    flat_e_local = (idx % epr).reshape(-1)
    flat_w = w.reshape(-1)
    tok_of = jnp.repeat(jnp.arange(T), c.top_k)

    def pack(dst):
        mine = flat_dst_rank == dst
        score = jnp.where(mine, -jnp.arange(T * c.top_k, dtype=jnp.float32), -jnp.inf)
        _, slots = lax.top_k(score, cap)
        valid = mine[slots]
        return slots, valid

    slots, valid = jax.vmap(pack)(jnp.arange(n_ranks))       # (R, cap)
    toks = tok_of[slots]
    payload = x_flat[toks] * valid[..., None].astype(x_flat.dtype)      # (R, cap, d)
    hdr_e = jnp.where(valid, flat_e_local[slots], 0)                    # (R, cap)
    hdr_w = jnp.where(valid, flat_w[slots], 0.0)

    # --- outbound hop(s): Data Distributor -> routers -> remote Collector
    rx = a2a(payload)                                        # (R, cap, d) from each src
    rhdr_e = a2a(hdr_e[..., None])[..., 0]
    rvalid = a2a(valid[..., None].astype(jnp.int32))[..., 0] > 0

    # --- local expert compute on received packets
    flat_rx = rx.reshape(-1, d)                              # (R*cap, d)
    flat_e = rhdr_e.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, epr, dtype=x_flat.dtype) * rvalid.reshape(-1, 1)
    xe = jnp.einsum("td,te->etd", flat_rx, onehot)           # (epr, R*cap, d)
    ye = _expert_ffn(xe, wg, wu, wd, c.act)
    y_flat = jnp.einsum("etd,te->td", ye, onehot)            # (R*cap, d)

    # --- return hop(s): same schedule back to the source rank
    back = a2a(y_flat.reshape(n_ranks, cap, d))              # (R, cap, d), slot-aligned
    contrib = back * (hdr_w[..., None]).astype(back.dtype) * valid[..., None].astype(back.dtype)
    out = jnp.zeros_like(x_flat)
    out = out.at[toks.reshape(-1)].add(contrib.reshape(-1, d))
    return out, (me, ce)


# ---------------------------------------------------------------------------
# public layer
# ---------------------------------------------------------------------------

def moe_apply(params: dict, x: jax.Array, c: MoEConfig) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).  Engine per ``c.impl``."""
    if c.impl == "dense":
        return dense_ref(params, x, c)

    mesh = get_abstract_mesh()
    if mesh is None or "model" not in (mesh.axis_names or ()):
        # no mesh context (unit tests / single host): run the oracle
        return dense_ref(params, x, c)
    n_ranks = mesh.shape["model"]
    if c.n_experts % n_ranks:
        return dense_ref(params, x, c)

    B, S, d = x.shape
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_batch = 1
    for a in batch_axes:
        n_batch *= mesh.shape[a]
    if B % max(n_batch, 1):
        batch_axes = ()          # tiny-batch decode: replicate over data axes
    bspec = batch_axes if batch_axes else None
    wspec = P("model", None, None)
    all_axes = batch_axes + ("model",)
    impl = c.impl
    if impl == "noc" and (S < n_ranks or S % n_ranks):
        impl = "gather"          # decode steps: no sequence axis to shard

    def _aux_of(me, ce, axes):
        if axes:
            me = lax.pmean(me, axes)
            ce = lax.pmean(ce, axes)
        return c.n_experts * jnp.sum(me * ce)

    if impl == "gather":
        def fn(xl, wr, wg, wu, wd):
            T = xl.shape[0] * xl.shape[1]
            out, (me, ce) = _gather_local(xl.reshape(T, d), wr, wg, wu, wd, c,
                                          n_ranks, "model")
            return out.reshape(xl.shape), _aux_of(me, ce, batch_axes)
        sm = shard_map(
            fn, mesh=mesh,
            in_specs=(P(bspec, None, None), P(), wspec, wspec, wspec),
            out_specs=(P(bspec, None, None), P()),
            check_vma=False)
        out, aux = sm(x, params["router"].astype(x.dtype), params["gate"].astype(x.dtype),
                      params["up"].astype(x.dtype), params["down"].astype(x.dtype))
        return out, aux.reshape(())

    def fn(xl, wr, wg, wu, wd):
        xl2 = xl.reshape(-1, d)
        out, (me, ce) = _noc_local(xl2, wr, wg, wu, wd, c, n_ranks, "model")
        return out.reshape(xl.shape), _aux_of(me, ce, all_axes)
    sm = shard_map(
        fn, mesh=mesh,
        in_specs=(P(bspec, "model", None), P(), wspec, wspec, wspec),
        out_specs=(P(bspec, "model", None), P()),
        check_vma=False)
    out, aux = sm(x, params["router"].astype(x.dtype), params["gate"].astype(x.dtype),
                  params["up"].astype(x.dtype), params["down"].astype(x.dtype))
    return out, aux.reshape(())
