"""Mamba-1 selective SSM block (Jamba's attention-free mixer).

TPU adaptation: the CUDA selective-scan kernel fuses a sequential recurrence;
on TPU we use a *chunked* scan — within a chunk the linear recurrence
h_t = a_t·h_{t-1} + b_t is evaluated with ``lax.associative_scan`` (parallel,
VPU/MXU friendly), across chunks a ``lax.scan`` carries the (B, d_inner, N)
state.  Memory per step is O(B·Q·d_inner·N) for chunk Q instead of O(B·S·…)
(the assoc-scan-over-everything variant) or an S-step sequential loop.

Decode is the O(1) recurrent update on (conv_state, ssm_state).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..core.partition import constrain
from .layers import ParamSpec


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0     # 0 -> ceil(d/16)
    chunk: int = 256
    unroll: bool = False

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)


def mamba_specs(c: MambaConfig, dtype=jnp.float32) -> dict:
    d, di, N, R = c.d_model, c.d_inner, c.d_state, c.rank
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "ssm_inner"), dtype),
        "conv_w": ParamSpec((c.d_conv, di), (None, "ssm_inner"), dtype, init="small"),
        "conv_b": ParamSpec((di,), ("ssm_inner",), dtype, init="zeros"),
        "x_proj": ParamSpec((di, R + 2 * N), ("ssm_inner", None), dtype),
        "dt_w": ParamSpec((R, di), (None, "ssm_inner"), dtype),
        "dt_b": ParamSpec((di,), ("ssm_inner",), dtype, init="ones", scale=-4.6),  # softplus^-1(~0.01)
        "a_log": ParamSpec((di, N), ("ssm_inner", "ssm_state"), dtype, init="ones"),
        "d_skip": ParamSpec((di,), ("ssm_inner",), dtype, init="ones"),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed"), dtype),
    }


def init_mamba_cache(c: MambaConfig, batch: int, dtype=jnp.float32) -> dict:
    return {
        "conv": jnp.zeros((batch, c.d_conv - 1, c.d_inner), dtype),
        "ssm": jnp.zeros((batch, c.d_inner, c.d_state), dtype),
    }


def _conv_causal(x, w, b, state: Optional[jax.Array]):
    """x (B,S,di), w (K,di) depthwise.  state: (B,K-1,di) prior context."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):, :] if K > 1 else None
    return out, new_state


def _ssm_params(params, xc, c: MambaConfig):
    """xc (B,S,di) post-conv -> dt (B,S,di), B_in (B,S,N), C_out (B,S,N), A."""
    R, N = c.rank, c.d_state
    proj = xc @ params["x_proj"].astype(xc.dtype)
    dt_r, b_in, c_out = proj[..., :R], proj[..., R:R + N], proj[..., R + N:]
    # bias initialized to softplus^-1(~0.01) ≈ -4.6 (dt_b spec: ones × -4.6)
    dt = jax.nn.softplus(dt_r @ params["dt_w"].astype(xc.dtype)
                         - 4.6 * params["dt_b"].astype(xc.dtype))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    return dt, b_in, c_out, a


def _chunk_recurrence(h0, decay, inc):
    """h_t = decay_t * h_{t-1} + inc_t over axis 1 (chunk), assoc-scan.
    decay/inc: (B, Q, di, N); h0: (B, di, N)."""

    def combine(left, right):
        dl, il = left
        dr, ir = right
        return dl * dr, ir + dr * il

    dec, acc = lax.associative_scan(combine, (decay, inc), axis=1)
    h = acc + dec * h0[:, None]
    return h  # (B, Q, di, N) — all prefix states


def mamba_apply(params: dict, x: jax.Array, c: MambaConfig,
                cache: Optional[dict] = None) -> tuple[jax.Array, Optional[dict]]:
    """x (B,S,d) -> (out (B,S,d), cache')."""
    B, S, d = x.shape
    di, N = c.d_inner, c.d_state
    xz = x @ params["in_proj"].astype(x.dtype)
    xs, z = xz[..., :di], xz[..., di:]
    xs = constrain(xs, ("batch", "seq", "ssm_inner"))

    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = _conv_causal(xs, params["conv_w"].astype(x.dtype),
                                params["conv_b"].astype(x.dtype), conv_state)
    xc = jax.nn.silu(xc)
    dt, b_in, c_out, a = _ssm_params(params, xc, c)

    dt32 = dt.astype(jnp.float32)
    xc32 = xc.astype(jnp.float32)
    h_prev = (cache["ssm"].astype(jnp.float32) if cache is not None
              else jnp.zeros((B, di, N), jnp.float32))

    if S == 1:  # decode: single recurrent update
        decay = jnp.exp(dt32[:, 0, :, None] * a[None])                  # (B,di,N)
        inc = (dt32[:, 0, :, None] * xc32[:, 0, :, None]) * b_in[:, 0, None, :].astype(jnp.float32)
        h = decay * h_prev + inc
        y = jnp.einsum("bdn,bn->bd", h, c_out[:, 0].astype(jnp.float32))[:, None, :]
        new_h = h
    else:
        Q = min(c.chunk, S)
        pad = (-S) % Q
        if pad:
            dt32 = jnp.pad(dt32, ((0, 0), (0, pad), (0, 0)))
            xc32 = jnp.pad(xc32, ((0, 0), (0, pad), (0, 0)))
            b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
            c_out = jnp.pad(c_out, ((0, 0), (0, pad), (0, 0)))
        nq = (S + pad) // Q
        dtc = dt32.reshape(B, nq, Q, di).transpose(1, 0, 2, 3)
        xcc = xc32.reshape(B, nq, Q, di).transpose(1, 0, 2, 3)
        bc = b_in.reshape(B, nq, Q, N).transpose(1, 0, 2, 3).astype(jnp.float32)
        cc = c_out.reshape(B, nq, Q, N).transpose(1, 0, 2, 3).astype(jnp.float32)

        def step(h0, blk):
            dtq, xq, bq, cq = blk
            decay = jnp.exp(dtq[..., None] * a[None, None])              # (B,Q,di,N)
            inc = (dtq * xq)[..., None] * bq[:, :, None, :]
            hs = _chunk_recurrence(h0, decay, inc)
            yq = jnp.einsum("bqdn,bqn->bqd", hs, cq)
            return hs[:, -1], yq

        new_h, ys = lax.scan(step, h_prev, (dtc, xcc, bc, cc),
                             unroll=nq if c.unroll else 1)
        y = ys.transpose(1, 0, 2, 3).reshape(B, nq * Q, di)[:, :S]

    y = y + xc32[:, :S] * params["d_skip"].astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": new_h}
    return out, new_cache


def mamba_scan_ref(params: dict, x: jax.Array, c: MambaConfig) -> jax.Array:
    """Sequential-scan oracle (step-by-step decode semantics) for tests."""
    B, S, d = x.shape
    cache = init_mamba_cache(c, B)
    outs = []
    for t in range(S):
        o, cache = mamba_apply(params, x[:, t:t + 1], c, cache)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)
