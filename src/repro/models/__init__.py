"""Model zoo: config-driven architectures assembled in transformer.py."""
from . import attention, layers, mla, moe, ssm, transformer, xlstm
from .moe import MoEConfig, MoEDispatchStats, dispatch_capacity
from .transformer import (abstract_params, decode_step, forward, init_cache, loss,
                          prefill)
