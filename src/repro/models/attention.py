"""GQA / MHA attention with RoPE, QK-norm, KV cache, and three execution
impls:

* ``naive``   — full logits materialized (small shapes / decode)
* ``blocked`` — pure-jnp online-softmax over KV blocks (lax.scan) — the
                memory-roofline-honest path big pjit graphs lower (peak
                O(S·bkv) instead of O(S·T))
* ``flash``   — the Pallas kernel (TPU target; interpret-validated on CPU)

Cross-attention (whisper) = ``kv_override`` + causal=False.  Decode = S==1
with a preallocated ring cache written at ``cache["idx"]``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..core.partition import constrain
from ..kernels import ops as kops
from .layers import ParamSpec, rms_norm, rope


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    impl: str = "blocked"           # naive | blocked | flash
    bkv: int = 512
    logit_softcap: float = 0.0
    seq_shard: bool = False         # long-context: KV seq axis over 'data'
    unroll: bool = False            # analysis mode: unroll the KV-block scan
    compute_dtype: str = "f32"      # f32 (baseline) | bf16 (beyond-paper opt:
                                    #   bf16 operands, f32 accumulation)


def attn_specs(c: AttnConfig, dtype=jnp.float32) -> dict:
    d, H, Hkv, D = c.d_model, c.n_heads, c.n_kv_heads, c.head_dim
    sp = {
        "wq": ParamSpec((d, H, D), ("embed", "heads", "head_dim"), dtype),
        "wk": ParamSpec((d, Hkv, D), ("embed", "kv_heads", "head_dim"), dtype),
        "wv": ParamSpec((d, Hkv, D), ("embed", "kv_heads", "head_dim"), dtype),
        "wo": ParamSpec((H, D, d), ("heads", "head_dim", "embed"), dtype),
    }
    if c.qk_norm:
        sp["q_norm"] = ParamSpec((D,), (None,), dtype, init="ones")
        sp["k_norm"] = ParamSpec((D,), (None,), dtype, init="ones")
    return sp


def cache_axes(c: AttnConfig) -> tuple:
    # long-context: shard head_dim, NOT seq — a dynamic-update-slice along a
    # sharded dim forces halo logic in the SPMD partitioner (pathological
    # compile); head_dim sharding keeps the token append shard-local and the
    # QK contraction reduces over 'data' with one small psum per layer.
    if c.seq_shard:
        return ("batch", "kv_heads", "seq", "head_dim_shard")
    return ("batch", "kv_heads", "seq", "head_dim")


def init_cache(c: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    axes = cache_axes(c)
    k = jnp.zeros((batch, c.n_kv_heads, max_len, c.head_dim), dtype)
    v = jnp.zeros((batch, c.n_kv_heads, max_len, c.head_dim), dtype)
    return {"k": constrain(k, axes), "v": constrain(v, axes),
            "idx": jnp.zeros((), jnp.int32)}


def _qkv(params, x, c: AttnConfig, positions):
    q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bhsk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bhsk", x, params["wv"].astype(x.dtype))
    if c.qk_norm:
        q = rms_norm(q, params["q_norm"].astype(x.dtype))
        k = rms_norm(k, params["k_norm"].astype(x.dtype))
    if c.use_rope:
        # rope expects (..., S, D); bring seq before head_dim
        q = rope(q.swapaxes(1, 2), positions, c.rope_theta).swapaxes(1, 2)
        k = rope(k.swapaxes(1, 2), positions, c.rope_theta).swapaxes(1, 2)
    return q, k, v


def _naive(q, k, v, causal: bool, kv_len, softcap: float, q_offset=None,
           compute_dtype: str = "f32"):
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    g = Hq // Hkv
    if compute_dtype == "bf16":
        # beyond-paper: bf16 operands + f32 accumulation; fold the GQA group
        # into the q row dim so the KV cache is streamed ONCE per kv head
        # (not once per query group).
        qg = q.reshape(B, Hkv, g * S, D)
        s = jax.lax.dot_general(
            qg, k, (((3,), (3,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32) * (D ** -0.5)   # (B,Hkv,gS,T)
        s = s.reshape(B, Hkv, g, S, T)
    else:
        qg = q.reshape(B, Hkv, g, S, D).astype(jnp.float32)
        s = jnp.einsum("bhgsd,bhtd->bhgst", qg, k.astype(jnp.float32)) * (D ** -0.5)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    t_ids = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        off = (T - S) if q_offset is None else q_offset
        mask = mask & (t_ids[None, :] <= (jnp.arange(S)[:, None] + off))
    if kv_len is not None:
        mask = mask & (t_ids[None, :] < kv_len)
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    if compute_dtype == "bf16":
        pg = p.reshape(B, Hkv, g * S, T).astype(v.dtype)
        o = jax.lax.dot_general(pg, v, (((3,), (2,)), ((0, 1), (0, 1))),
                                preferred_element_type=jnp.float32)
        o = o.reshape(B, Hkv, g, S, v.shape[-1])
    else:
        o = jnp.einsum("bhgst,bhtd->bhgsd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, S, v.shape[-1]).astype(q.dtype)


def _blocked(q, k, v, causal: bool, kv_len, bkv: int, softcap: float, q_offset=None,
             unroll: bool = False, compute_dtype: str = "f32"):
    """Online-softmax scan over KV blocks (flash algorithm in jnp)."""
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    if T <= bkv:
        return _naive(q, k, v, causal, kv_len, softcap, q_offset, compute_dtype)
    pad = (-T) % bkv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nblk = (T + pad) // bkv
    g = Hq // Hkv
    cdt = jnp.bfloat16 if compute_dtype == "bf16" else jnp.float32
    qg = (q.reshape(B, Hkv, g, S, D).astype(cdt)) * jnp.asarray(D ** -0.5, cdt)
    kb = k.reshape(B, Hkv, nblk, bkv, D).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, Hkv, nblk, bkv, v.shape[-1]).transpose(2, 0, 1, 3, 4)
    q_ids = jnp.arange(S)[:, None]

    def body(carry, blk):
        acc, m, lse = carry
        kblk, vblk, t0 = blk
        s = jnp.einsum("bhgsd,bhtd->bhgst", qg, kblk.astype(cdt),
                       preferred_element_type=jnp.float32)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        t_ids = t0 + jnp.arange(bkv)[None, :]
        mask = t_ids < T
        if causal:
            off = (T - S) if q_offset is None else q_offset
            mask = mask & (t_ids <= q_ids + off)
        if kv_len is not None:
            mask = mask & (t_ids < kv_len)
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        acc = acc * alpha + jnp.einsum("bhgst,bhtd->bhgsd", p.astype(cdt),
                                       vblk.astype(cdt),
                                       preferred_element_type=jnp.float32)
        lse = lse * alpha + jnp.sum(p, axis=-1, keepdims=True)
        return (acc, m_new, lse), None

    Dv = v.shape[-1]
    acc0 = jnp.zeros((B, Hkv, g, S, Dv), jnp.float32)
    m0 = jnp.full((B, Hkv, g, S, 1), -1e30, jnp.float32)
    lse0 = jnp.zeros((B, Hkv, g, S, 1), jnp.float32)
    t0s = jnp.arange(nblk) * bkv
    (acc, m, lse), _ = lax.scan(jax.checkpoint(body), (acc0, m0, lse0), (kb, vb, t0s),
                              unroll=nblk if unroll else 1)
    o = acc / jnp.maximum(lse, 1e-30)
    return o.reshape(B, Hq, S, Dv).astype(q.dtype)


def attention(params: dict, x: jax.Array, c: AttnConfig, *,
              positions: Optional[jax.Array] = None,
              cache: Optional[dict] = None,
              kv_override: Optional[tuple[jax.Array, jax.Array]] = None,
              causal: bool = True) -> tuple[jax.Array, Optional[dict]]:
    """x: (B, S, d).  Returns (out (B, S, d), updated cache or None)."""
    B, S, d = x.shape
    if positions is None:
        base = cache["idx"] if cache is not None else 0
        positions = base + jnp.arange(S)[None, :].astype(jnp.int32)
        positions = jnp.broadcast_to(positions, (B, S))

    if kv_override is not None:
        q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"].astype(x.dtype))
        if c.qk_norm:
            q = rms_norm(q, params["q_norm"].astype(x.dtype))
        k, v = kv_override
        kv_len = None
        caus = False
        q_off = None
        new_cache = cache
    else:
        q, k, v = _qkv(params, x, c, positions)
        kv_len = None
        caus = causal
        q_off = None
        new_cache = None
        if cache is not None:
            idx = cache["idx"]
            ck = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, 0, idx, 0))
            cv = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, 0, idx, 0))
            axes = cache_axes(c)
            ck, cv = constrain(ck, axes), constrain(cv, axes)
            new_cache = {"k": ck, "v": cv, "idx": idx + S}
            k, v = ck.astype(x.dtype), cv.astype(x.dtype)
            kv_len = idx + S
            q_off = idx  # queries sit at absolute positions idx..idx+S-1

    q = constrain(q, ("batch", "heads", "seq", "head_dim"))
    if c.impl == "flash" and S > 1 and kv_len is None:
        o = kops.flash_attention(q, k, v, caus, True)
    elif c.impl == "blocked":
        o = _blocked(q, k, v, caus, kv_len, c.bkv, c.logit_softcap, q_off,
                     unroll=c.unroll, compute_dtype=c.compute_dtype)
    else:
        o = _naive(q, k, v, caus, kv_len, c.logit_softcap, q_off,
                   compute_dtype=c.compute_dtype)
    o = constrain(o, ("batch", "heads", "seq", "head_dim"))
    out = jnp.einsum("bhsk,hkd->bsd", o, params["wo"].astype(x.dtype))
    return out, new_cache
