"""Parameter machinery + basic layers shared by every architecture.

Params are plain nested-dict pytrees.  The single source of truth for shapes,
dtypes, *and logical sharding axes* is the abstract spec tree built by each
model's ``abstract_params``: every leaf is a ``ParamSpec``.  From it we derive
(1) materialized params, (2) PartitionSpecs for pjit, (3) parameter counts —
so the dry-run, the trainer, and the roofline all agree by construction.

Logical axis names are resolved by ``core.partition.DEFAULT_RULES``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Mapping, Optional

import jax
import jax.numpy as jnp

from ..core.partition import DEFAULT_RULES, constrain, logical_to_spec


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "fan_in"        # fan_in | zeros | ones | embed | small
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def spec_tree_map(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def init_param(key: jax.Array, spec: ParamSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape) * spec.scale).astype(spec.dtype)
    if spec.init == "small":
        return (jax.random.normal(key, spec.shape) * 0.02 * spec.scale).astype(spec.dtype)
    # fan_in
    fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.shape[0], 1)
    if len(spec.shape) >= 3:  # stacked/layered weights: fan-in is the middle dim
        fan_in = spec.shape[-2]
    std = spec.scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape) * std).astype(spec.dtype)


def init_params(spec_tree, key: jax.Array):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [init_param(k, s) for k, s in zip(keys, leaves)])


def param_pspecs(spec_tree, rules: Mapping = DEFAULT_RULES, mesh_axes=None,
                 mesh_shape=None):
    return spec_tree_map(
        lambda s: logical_to_spec(s.axes, rules, mesh_axes, dims=s.shape,
                                  mesh_shape=mesh_shape), spec_tree)


def param_shapes(spec_tree):
    return spec_tree_map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree)


def count_params(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return sum(int(math.prod(s.shape)) for s in leaves)


# ---------------------------------------------------------------------------
# basic ops
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma + beta


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
         rope_dim: Optional[int] = None) -> jax.Array:
    """x: (..., S, H, D) or (..., S, D); positions: (..., S)."""
    d = rope_dim or x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    while cos.ndim < x.ndim:
        cos, sin = cos[..., None, :], sin[..., None, :]             # add head axis
    x1, x2 = x[..., :half], x[..., half:d]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([rot, x[..., d:]], axis=-1).astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array,
           act: str = "silu") -> jax.Array:
    g = x @ w_gate
    u = x @ w_up
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
    h = constrain(g * u, ("batch", "seq", "mlp"))
    return h @ w_down


def mlp_specs(d: int, ff: int, dtype, gated: bool = True) -> dict:
    sp = {
        "up": ParamSpec((d, ff), ("embed", "mlp"), dtype),
        "down": ParamSpec((ff, d), ("mlp", "embed"), dtype),
    }
    if gated:
        sp["gate"] = ParamSpec((d, ff), ("embed", "mlp"), dtype)
    return sp


def mlp_apply(params: dict, x, act: str = "silu"):
    if "gate" in params:
        return swiglu(x, params["gate"].astype(x.dtype), params["up"].astype(x.dtype),
                      params["down"].astype(x.dtype), act=act)
    h = x @ params["up"].astype(x.dtype)
    h = jax.nn.silu(h) if act == "silu" else jax.nn.gelu(h, approximate=True)
    h = constrain(h, ("batch", "seq", "mlp"))
    return h @ params["down"].astype(x.dtype)


def stack_specs(spec_tree, n: int):
    """Prepend a scanned-layers axis to every ParamSpec in the tree."""
    return spec_tree_map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.dtype, s.init, s.scale),
        spec_tree)


def cross_entropy(logits: jax.Array, labels: jax.Array, ignore_id: int = -1):
    """logits (B,S,V) possibly vocab-sharded; labels (B,S).  Mean NLL."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
