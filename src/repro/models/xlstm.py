"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential), per arXiv:2405.04517.

TPU adaptation (recorded in DESIGN.md):
* the mLSTM recurrence C_t = f_t C_{t-1} + i_t k_t v_tᵀ is linear, so the
  training/prefill path uses a *chunkwise* form — intra-chunk attention-style
  matmuls with a log-gate decay matrix (MXU work), inter-chunk a scanned
  (B, H, dk, dv) carry with running stabilizers (exp-gating never overflows).
  The sequential scan is kept as the oracle + decode path (property-tested
  equal).
* projections and gates are head-local (block-diagonal), which makes heads a
  clean tensor-parallel axis; the original's full d×d mixing would shard the
  same logical axis on both sides of a square matmul.
* sLSTM's h_{t-1}→gates feedback is inherently sequential; it stays a
  ``lax.scan`` (the paper's own formulation), small per-step.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from .layers import ParamSpec, rms_norm


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int
    proj_factor: float = 2.0          # mLSTM up-projection
    d_conv: int = 4
    chunk: int = 128
    unroll: bool = False
    slstm_ff_factor: float = 4.0 / 3.0

    @property
    def d_inner(self) -> int:
        return int(self.proj_factor * self.d_model)

    @property
    def dh(self) -> int:  # mLSTM head dim (of d_inner)
        return self.d_inner // self.n_heads

    @property
    def dh_model(self) -> int:  # sLSTM head dim (of d_model)
        return self.d_model // self.n_heads

    @property
    def slstm_ff(self) -> int:
        return int(self.slstm_ff_factor * self.d_model)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_specs(c: XLSTMConfig, dtype=jnp.float32) -> dict:
    d, di, H, dh = c.d_model, c.d_inner, c.n_heads, c.dh
    return {
        "up": ParamSpec((d, 2 * di), ("embed", "ssm_inner"), dtype),
        "conv_w": ParamSpec((c.d_conv, di), (None, "ssm_inner"), dtype, init="small"),
        "conv_b": ParamSpec((di,), ("ssm_inner",), dtype, init="zeros"),
        "wq": ParamSpec((H, dh, dh), ("heads", None, None), dtype),
        "wk": ParamSpec((H, dh, dh), ("heads", None, None), dtype),
        "wv": ParamSpec((H, dh, dh), ("heads", None, None), dtype),
        "wi": ParamSpec((H, dh), ("heads", None), dtype, init="small"),
        "bi": ParamSpec((H,), ("heads",), dtype, init="zeros"),
        "wf": ParamSpec((H, dh), ("heads", None), dtype, init="small"),
        "bf": ParamSpec((H,), ("heads",), dtype, init="ones", scale=3.0),
        "norm": ParamSpec((di,), ("ssm_inner",), dtype, init="ones"),
        "down": ParamSpec((di, d), ("ssm_inner", "embed"), dtype),
    }


def init_mlstm_cache(c: XLSTMConfig, batch: int, dtype=jnp.float32) -> dict:
    H, dh = c.n_heads, c.dh
    return {
        "conv": jnp.zeros((batch, c.d_conv - 1, c.d_inner), dtype),
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def _mlstm_qkv_gates(params, x, c: XLSTMConfig, conv_state):
    from .ssm import _conv_causal

    B, S, _ = x.shape
    H, dh = c.n_heads, c.dh
    up = x @ params["up"].astype(x.dtype)
    xi, z = up[..., :c.d_inner], up[..., c.d_inner:]
    xc, new_conv = _conv_causal(xi, params["conv_w"].astype(x.dtype),
                                params["conv_b"].astype(x.dtype), conv_state)
    xc = jax.nn.silu(xc)
    xh = xc.reshape(B, S, H, dh)
    q = jnp.einsum("bshd,hde->bshe", xh, params["wq"].astype(x.dtype)) * (dh ** -0.5)
    k = jnp.einsum("bshd,hde->bshe", xh, params["wk"].astype(x.dtype))
    v = jnp.einsum("bshd,hde->bshe", xi.reshape(B, S, H, dh),
                   params["wv"].astype(x.dtype))
    li = (jnp.einsum("bshd,hd->bsh", xh, params["wi"].astype(x.dtype))
          + params["bi"].astype(x.dtype)).astype(jnp.float32)
    lf_raw = (jnp.einsum("bshd,hd->bsh", xh, params["wf"].astype(x.dtype))
              + 3.0 * params["bf"].astype(x.dtype)).astype(jnp.float32)
    lf = jax.nn.log_sigmoid(lf_raw)
    return q, k, v, z, li, lf, new_conv


def _mlstm_decode_step(q, k, v, li, lf, state):
    """Single-step stabilized recurrence.  q/k/v: (B,H,dh); li/lf: (B,H)."""
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    fp = jnp.exp(lf + m - m_new)[..., None, None]
    ip = jnp.exp(li - m_new)[..., None, None]
    k32, v32, q32 = (t.astype(jnp.float32) for t in (k, v, q))
    C_new = fp * C + ip * (k32[..., :, None] * v32[..., None, :])
    n_new = fp[..., 0] * n + ip[..., 0] * k32
    num = jnp.einsum("bhkv,bhk->bhv", C_new, q32)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q32))
    h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return h, {"C": C_new, "n": n_new, "m": m_new}


def _mlstm_chunked(q, k, v, li, lf, state, chunk: int, chunk_unroll: bool = False):
    """Chunkwise-parallel mLSTM.  q/k/v (B,S,H,dh); li/lf (B,S,H)."""
    B, S, H, dh = q.shape
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        zq = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = jnp.pad(q, zq), jnp.pad(k, zq), jnp.pad(v, zq)
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
    nq = (S + pad) // Q

    def part(t):  # (B, S+, H, ...) -> (nq, B, H, Q, ...)
        t = t.reshape(B, nq, Q, *t.shape[2:])
        return jnp.moveaxis(t, (1, 3), (0, 2)) if t.ndim == 5 else jnp.moveaxis(t, (1, 3), (0, 3))

    qc = part(q).astype(jnp.float32)       # (nq,B,H,Q,dh)
    kc = part(k).astype(jnp.float32)
    vc = part(v).astype(jnp.float32)
    lic = jnp.moveaxis(li.reshape(B, nq, Q, H), (1, 3), (0, 2))  # (nq,B,H,Q)
    lfc = jnp.moveaxis(lf.reshape(B, nq, Q, H), (1, 3), (0, 2))

    def step(carry, blk):
        Ch, nh, mc = carry                     # stabilized carry: true C = Ch·exp(mc)
        qb, kb, vb, lib, lfb = blk             # (B,H,Q,·)
        A = jnp.cumsum(lfb, axis=-1)           # inclusive decay prefix (B,H,Q)
        # intra-chunk log decay matrix: logD[t,s] = A_t - A_s + li_s, s<=t
        logD = A[..., :, None] - A[..., None, :] + lib[..., None, :]
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        logD = jnp.where(tri, logD, -jnp.inf)
        inter_log = A + mc[..., None]          # carry contribution (B,H,Q)
        m_t = jnp.maximum(jnp.max(logD, axis=-1), inter_log)
        m_t = jnp.maximum(m_t, -1e30)
        Dm = jnp.exp(logD - m_t[..., None])                      # (B,H,Q,Q)
        w_inter = jnp.exp(inter_log - m_t)                       # (B,H,Q)
        scores = jnp.einsum("bhtd,bhsd->bhts", qb, kb) * Dm
        num = (jnp.einsum("bhts,bhsv->bhtv", scores, vb)
               + w_inter[..., None] * jnp.einsum("bhkv,bhtk->bhtv", Ch, qb))
        den = (jnp.sum(scores, axis=-1)
               + w_inter * jnp.einsum("bhk,bhtk->bht", nh, qb))
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # carry update
        A_Q = A[..., -1]                                          # (B,H)
        s_log = A_Q[..., None] - A + lib                          # decay of s to chunk end
        mc_new = jnp.maximum(A_Q + mc, jnp.max(s_log, axis=-1))
        wk_s = jnp.exp(s_log - mc_new[..., None])                 # (B,H,Q)
        Ch_new = (jnp.exp(A_Q + mc - mc_new)[..., None, None] * Ch
                  + jnp.einsum("bhs,bhsk,bhsv->bhkv", wk_s, kb, vb))
        nh_new = (jnp.exp(A_Q + mc - mc_new)[..., None] * nh
                  + jnp.einsum("bhs,bhsk->bhk", wk_s, kb))
        return (Ch_new, nh_new, mc_new), h

    carry0 = (state["C"], state["n"], state["m"])
    (Cf, nf, mf), hs = lax.scan(step, carry0, (qc, kc, vc, lic, lfc),
                                unroll=nq if chunk_unroll else 1)
    h = jnp.moveaxis(hs, (0, 2), (1, 3)).reshape(B, nq * Q, H, dh)[:, :S]
    return h, {"C": Cf, "n": nf, "m": mf}


def mlstm_apply(params: dict, x: jax.Array, c: XLSTMConfig,
                cache: Optional[dict] = None) -> tuple[jax.Array, Optional[dict]]:
    B, S, d = x.shape
    H, dh = c.n_heads, c.dh
    conv_state = cache["conv"] if cache is not None else None
    q, k, v, z, li, lf, new_conv = _mlstm_qkv_gates(params, x, c, conv_state)
    state = ({k2: cache[k2] for k2 in ("C", "n", "m")} if cache is not None
             else {"C": jnp.zeros((B, H, dh, dh), jnp.float32),
                   "n": jnp.zeros((B, H, dh), jnp.float32),
                   "m": jnp.full((B, H), -1e30, jnp.float32)})
    if S == 1:
        h, new_state = _mlstm_decode_step(q[:, 0], k[:, 0], v[:, 0],
                                          li[:, 0], lf[:, 0], state)
        h = h[:, None]
    else:
        h, new_state = _mlstm_chunked(q, k, v, li, lf, state, c.chunk, c.unroll)
    h = h.reshape(B, S, c.d_inner).astype(x.dtype)
    h = rms_norm(h.reshape(B, S, H, dh), jnp.ones((dh,), x.dtype)).reshape(B, S, c.d_inner)
    h = h * params["norm"].astype(x.dtype)
    h = h * jax.nn.silu(z)
    out = h @ params["down"].astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), **new_state}
    return out, new_cache


def mlstm_seq_ref(params: dict, x: jax.Array, c: XLSTMConfig) -> jax.Array:
    """Step-by-step oracle for the chunked path."""
    B, S, _ = x.shape
    cache = init_mlstm_cache(c, B, x.dtype)
    outs = []
    for t in range(S):
        o, cache = mlstm_apply(params, x[:, t:t + 1], c, cache)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_specs(c: XLSTMConfig, dtype=jnp.float32) -> dict:
    d, H, dh = c.d_model, c.n_heads, c.dh_model
    sp = {}
    for g in ("z", "i", "f", "o"):
        sp[f"w{g}"] = ParamSpec((d, H, dh), ("embed", "heads", None), dtype)
        sp[f"r{g}"] = ParamSpec((H, dh, dh), ("heads", None, None), dtype, init="small")
        sp[f"b{g}"] = ParamSpec((H, dh), ("heads", None), dtype,
                                init="ones" if g == "f" else "zeros")
    sp["norm"] = ParamSpec((d,), ("embed",), dtype, init="ones")
    sp["ff_up"] = ParamSpec((d, c.slstm_ff), ("embed", "mlp"), dtype)
    sp["ff_down"] = ParamSpec((c.slstm_ff, d), ("mlp", "embed"), dtype)
    return sp


def init_slstm_cache(c: XLSTMConfig, batch: int, dtype=jnp.float32) -> dict:
    H, dh = c.n_heads, c.dh_model
    return {"c": jnp.zeros((batch, H, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
            "m": jnp.full((batch, H, dh), -1e30, jnp.float32),
            "h": jnp.zeros((batch, H, dh), jnp.float32)}


def slstm_apply(params: dict, x: jax.Array, c: XLSTMConfig,
                cache: Optional[dict] = None) -> tuple[jax.Array, Optional[dict]]:
    B, S, d = x.shape
    H, dh = c.n_heads, c.dh_model
    pre = {g: (jnp.einsum("bsd,dhe->bshe", x, params[f"w{g}"].astype(x.dtype))
               + (3.0 if g == "f" else 1.0) * params[f"b{g}"].astype(x.dtype)
               ).astype(jnp.float32)
           for g in ("z", "i", "f", "o")}
    state0 = (cache if cache is not None else init_slstm_cache(c, B))

    def step(st, ins):
        zt, it, ft, ot = ins
        h_prev = st["h"]
        rz = jnp.einsum("bhe,hef->bhf", h_prev, params["rz"].astype(jnp.float32))
        ri = jnp.einsum("bhe,hef->bhf", h_prev, params["ri"].astype(jnp.float32))
        rf = jnp.einsum("bhe,hef->bhf", h_prev, params["rf"].astype(jnp.float32))
        ro = jnp.einsum("bhe,hef->bhf", h_prev, params["ro"].astype(jnp.float32))
        z = jnp.tanh(zt + rz)
        li = it + ri
        lf = jax.nn.log_sigmoid(ft + rf)
        o = jax.nn.sigmoid(ot + ro)
        m_new = jnp.maximum(lf + st["m"], li)
        fp = jnp.exp(lf + st["m"] - m_new)
        ip = jnp.exp(li - m_new)
        c_new = fp * st["c"] + ip * z
        n_new = fp * st["n"] + ip
        h = o * c_new / jnp.maximum(n_new, 1e-6)
        new = {"c": c_new, "n": n_new, "m": m_new, "h": h}
        return new, h

    xs = tuple(jnp.moveaxis(pre[g], 1, 0) for g in ("z", "i", "f", "o"))
    new_state, hs = lax.scan(step, state0, xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    h = rms_norm(h, params["norm"].astype(x.dtype))
    h = h + jax.nn.gelu(h @ params["ff_up"].astype(x.dtype),
                        approximate=True) @ params["ff_down"].astype(x.dtype)
    new_cache = new_state if cache is not None else None
    return h, new_cache
