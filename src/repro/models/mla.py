"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

The KV cache stores only the *compressed latent* (kv_lora_rank) plus the
shared RoPE key — for MiniCPM3 that is 256+32 floats/token vs
40 heads × 2 × 64 = 5120 for vanilla GQA: a ~18× cut in exactly the traffic
the paper's quasi-SERDES narrow links carry when the cache is partitioned
across chips (synergy noted in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..core.partition import constrain
from .attention import _blocked, _naive
from .layers import ParamSpec, rms_norm, rope


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_dim: int = 64
    rope_theta: float = 10000.0
    impl: str = "blocked"
    bkv: int = 512
    unroll: bool = False
    compute_dtype: str = "f32"
    absorb: bool = False   # beyond-paper: absorbed formulation — attention in
                           # the compressed latent space, no (T,H,·) expansion


def mla_specs(c: MLAConfig, dtype=jnp.float32) -> dict:
    d, H = c.d_model, c.n_heads
    return {
        "q_a": ParamSpec((d, c.q_lora_rank), ("embed", None), dtype),
        "q_a_norm": ParamSpec((c.q_lora_rank,), (None,), dtype, init="ones"),
        "q_b": ParamSpec((c.q_lora_rank, H, c.qk_nope_dim + c.qk_rope_dim),
                         (None, "heads", None), dtype),
        "kv_a": ParamSpec((d, c.kv_lora_rank + c.qk_rope_dim), ("embed", None), dtype),
        "kv_a_norm": ParamSpec((c.kv_lora_rank,), (None,), dtype, init="ones"),
        "kv_b": ParamSpec((c.kv_lora_rank, H, c.qk_nope_dim + c.v_dim),
                          (None, "heads", None), dtype),
        "wo": ParamSpec((H, c.v_dim, d), ("heads", None, "embed"), dtype),
    }


def init_mla_cache(c: MLAConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    return {
        "ckv": jnp.zeros((batch, max_len, c.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, c.qk_rope_dim), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def mla_apply(params: dict, x: jax.Array, c: MLAConfig, *,
              positions: Optional[jax.Array] = None,
              cache: Optional[dict] = None) -> tuple[jax.Array, Optional[dict]]:
    B, S, d = x.shape
    H = c.n_heads
    if positions is None:
        base = cache["idx"] if cache is not None else 0
        positions = base + jnp.broadcast_to(jnp.arange(S)[None, :], (B, S)).astype(jnp.int32)

    cq = rms_norm(x @ params["q_a"].astype(x.dtype), params["q_a_norm"].astype(x.dtype))
    q = jnp.einsum("bsr,rhk->bshk", cq, params["q_b"].astype(x.dtype))
    q_nope, q_rope = q[..., :c.qk_nope_dim], q[..., c.qk_nope_dim:]
    q_rope = rope(q_rope, positions, c.rope_theta)

    ckv_full = x @ params["kv_a"].astype(x.dtype)
    ckv = rms_norm(ckv_full[..., :c.kv_lora_rank], params["kv_a_norm"].astype(x.dtype))
    k_rope_new = rope(ckv_full[..., c.kv_lora_rank:], positions, c.rope_theta)

    kv_len = None
    q_off = None
    new_cache = None
    if cache is not None:
        idx = cache["idx"]
        q_off = idx
        ckv_all = lax.dynamic_update_slice(cache["ckv"], ckv.astype(cache["ckv"].dtype),
                                           (0, idx, 0))
        kr_all = lax.dynamic_update_slice(cache["k_rope"],
                                          k_rope_new.astype(cache["k_rope"].dtype),
                                          (0, idx, 0))
        new_cache = {"ckv": ckv_all, "k_rope": kr_all, "idx": idx + S}
        ckv_use, kr_use = ckv_all.astype(x.dtype), kr_all.astype(x.dtype)
        kv_len = idx + S
    else:
        ckv_use, kr_use = ckv, k_rope_new

    T = ckv_use.shape[1]
    if c.absorb:
        # absorbed formulation (beyond-paper opt): fold kv_b's key half into
        # q, its value half into the output path — attention runs entirely in
        # the (kv_lora + rope)-dim latent space and the cache is never
        # expanded to per-head K/V.  Math identical to the expanded form.
        kv_b = params["kv_b"].astype(x.dtype)                  # (r, H, nope+v)
        kb, vb = kv_b[..., :c.qk_nope_dim], kv_b[..., c.qk_nope_dim:]
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, kb)       # (B,S,H,r)
        qh = jnp.concatenate([q_lat, q_rope], -1).transpose(0, 2, 1, 3)
        kh = jnp.concatenate([ckv_use, kr_use], -1)[:, None]   # (B,1,T,r+rope)
        vh = ckv_use[:, None]                                  # (B,1,T,r)
        # _naive/_blocked scale by sqrt(r+rope); the expanded form scales by
        # sqrt(nope+rope) — pre-scale q to compensate exactly.
        fix = ((c.kv_lora_rank + c.qk_rope_dim) ** 0.5
               / (c.qk_nope_dim + c.qk_rope_dim) ** 0.5)
        qh = qh * jnp.asarray(fix, qh.dtype)
        if c.impl == "naive" or S == 1:
            o_lat = _naive(qh, kh, vh, True, kv_len, 0.0, q_off, "bf16")
        else:
            o_lat = _blocked(qh, kh, vh, True, kv_len, c.bkv, 0.0, q_off,
                             unroll=c.unroll, compute_dtype="bf16")
        o = jnp.einsum("bhsr,rhv->bhsv", o_lat, vb)            # per-head values
    else:
        # expand latent -> per-head keys/values (the baseline formulation)
        kv = jnp.einsum("btr,rhk->bthk", ckv_use, params["kv_b"].astype(x.dtype))
        k_nope, v = kv[..., :c.qk_nope_dim], kv[..., c.qk_nope_dim:]
        k_rope_b = jnp.broadcast_to(kr_use[:, :, None, :], (B, T, H, c.qk_rope_dim))

        qh = jnp.concatenate([q_nope, q_rope], -1).transpose(0, 2, 1, 3)   # (B,H,S,Dq)
        kh = jnp.concatenate([k_nope, k_rope_b], -1).transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)                                       # (B,H,T,Dv)
        qh = constrain(qh, ("batch", "heads", "seq", "head_dim"))
        if c.impl == "naive" or S == 1:
            o = _naive(qh, kh, vh, True, kv_len, 0.0, q_off, c.compute_dtype)
        else:
            o = _blocked(qh, kh, vh, True, kv_len, c.bkv, 0.0, q_off,
                         unroll=c.unroll, compute_dtype=c.compute_dtype)
    out = jnp.einsum("bhsv,hvd->bsd", o, params["wo"].astype(x.dtype))
    return out, new_cache
