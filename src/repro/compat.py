"""Version portability shims for the pinned-vs-current jax API drift.

The repo targets the modern ``jax.shard_map`` / ``jax.set_mesh`` surface; on
older jax (< 0.5) those live at ``jax.experimental.shard_map.shard_map`` (with
``check_rep``/``auto`` instead of ``check_vma``/``axis_names``) and the
``Mesh`` context manager.  Every internal call site goes through these
wrappers so the same code runs on both.
"""
from __future__ import annotations

import jax


def axis_size(axis_name) -> int:
    """``lax.axis_size`` portable to old jax, where ``psum`` of a Python int
    is evaluated statically against the axis env (returns a concrete int)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


# New-style shard_map implies the modern partial-auto lowering; without it,
# sharding constraints inside a partially-manual region crash old XLA.
MODERN_SHARD_MAP = hasattr(jax, "shard_map")


def manual_axes_in_scope() -> set:
    """Mesh axis names currently bound as manual collectives axes (i.e. we are
    inside shard_map/pmap over them).  Sharding constraints must not mention
    these."""
    try:
        from jax._src import core as _core
        return set(_core.get_axis_env().axis_names())
    except Exception:
        return set()


def get_abstract_mesh():
    """Ambient mesh, portable: ``jax.sharding.get_abstract_mesh`` on new jax,
    the ``with mesh:`` thread-local physical mesh on old.  None if unset."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    try:
        from jax.interpreters.pxla import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True,
              axis_names=None):
    """``jax.shard_map`` portable across the 0.4.x → 0.5+ API rename.

    ``axis_names`` (new API) = the set of *manual* mesh axes; mapped onto the
    old API's complement ``auto`` set."""
    if hasattr(jax, "shard_map"):
        kw = dict(check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = dict(check_rep=check_vma)
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
