"""Jit'd public wrappers for the Pallas kernels.

Dispatch policy: kernels are written for TPU (the TARGET); on this CPU
container they execute through Pallas interpret mode (``interpret=None`` →
auto: real lowering on TPU, interpret elsewhere).  ``use_kernel=False`` falls
back to the pure-jnp oracle (the default inside big pjit graphs on CPU, where
the oracle is what XLA sees for the dry-run).
"""
from __future__ import annotations

import functools

import jax

from . import ref
from .flash_attention import flash_attention_pallas
from .gf2_bmvm import gf2_bmvm_pallas
from .histogram import particle_histogram_pallas
from .minsum import minsum_check_pallas


def _interp(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


# -- GF(2) BMVM -------------------------------------------------------------

def gf2_preprocess(a_bits, k):
    return ref.gf2_preprocess(a_bits, k)


def gf2_bmvm(lut, v_words, *, use_kernel: bool = True, interpret=None):
    if use_kernel:
        return gf2_bmvm_pallas(lut, v_words, interpret=_interp(interpret))
    return ref.gf2_bmvm(lut, v_words)


# -- LDPC min-sum ------------------------------------------------------------

def minsum_check(u, *, use_kernel: bool = True, interpret=None):
    if use_kernel:
        return minsum_check_pallas(u, interpret=_interp(interpret))
    return ref.minsum_check(u)


# -- particle filter ----------------------------------------------------------

def particle_histogram(bins, weights, ref_hist, *, n_bins=None, use_kernel: bool = True,
                       interpret=None):
    n_bins = n_bins or ref_hist.shape[-1]
    if use_kernel:
        return particle_histogram_pallas(bins, weights, ref_hist, n_bins=n_bins,
                                         interpret=_interp(interpret))
    hist = ref.weighted_histogram(bins, weights, n_bins)
    return hist, ref.bhattacharyya(hist, ref_hist)


# -- flash attention -----------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, use_kernel: bool = False,
                    interpret=None):
    """Differentiable attention: kernel forward (TPU) / jnp oracle fallback;
    backward always via the oracle's VJP (recompute strategy)."""
    if use_kernel:
        return flash_attention_pallas(q, k, v, causal=causal, interpret=_interp(interpret))
    return ref.mha(q, k, v, causal=causal)


def _fa_fwd(q, k, v, causal, use_kernel, interpret):
    out = flash_attention(q, k, v, causal, use_kernel, interpret)
    return out, (q, k, v)


def _fa_bwd(causal, use_kernel, interpret, resids, g):
    q, k, v = resids
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.mha(q_, k_, v_, causal=causal), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)
