"""Pallas TPU kernel: blocked flash attention forward (LM-stack hot spot).

This is the perf-critical compute layer of the LM generalization (prefill /
training attention).  Online-softmax over KV blocks: grid = (B, Hq,
q_blocks, kv_blocks) with the kv axis innermost; running max/denominator and
the output accumulator live in VMEM scratch and the output block is written
on the last kv step.  GQA is handled in the BlockSpec index maps (q head h
reads kv head h // group).  Block shapes default to MXU-aligned (128, 128).

Backward runs through the jnp reference (``ops.flash_attention`` wires a
custom_vjp whose bwd differentiates ref.mha) — training on TPU would swap in
a dedicated bwd kernel; serving only needs this forward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, bq: int, bkv: int, seq_q: int, seq_kv: int):
    qb = pl.program_id(2)
    tb = pl.program_id(3)
    n_tb = pl.num_programs(3)

    @pl.when(tb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)                 # (BKV, D)
    v = v_ref[0, 0].astype(jnp.float32)                 # (BKV, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (BQ, BKV)
    q_ids = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    t_ids = tb * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = t_ids < seq_kv
    if causal:
        # decode-style offset: query i attends to kv positions <= i + (T - S)
        mask &= t_ids <= q_ids + (seq_kv - seq_q)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                  # (BQ, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(tb == n_tb - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bkv", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = 128, bkv: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: (B, Hq, S, D); k/v: (B, Hkv, T, D), Hq % Hkv == 0 -> (B, Hq, S, D)."""
    B, Hq, S, D = q.shape
    _, Hkv, T, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    bq = min(bq, S)
    bkv = min(bkv, T)
    pad_q = (-S) % bq
    pad_t = (-T) % bkv
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
    grid = (B, Hq, (S + pad_q) // bq, (T + pad_t) // bkv)
    scale = D ** -0.5
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, bq=bq, bkv=bkv,
                          seq_q=S, seq_kv=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, t: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, i, t: (b, h // g, t, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, i, t: (b, h // g, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, t: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S + pad_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :S, :]
