"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Every function here is the semantic ground truth; kernels must match to
numerical tolerance across the shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# GF(2) BMVM — Williams' sub-quadratic algorithm (paper §VI)
# ---------------------------------------------------------------------------

def gf2_preprocess(a_bits: jax.Array, k: int) -> jax.Array:
    """One-time preprocessing (paper Fig. 13).

    a_bits: (n, n) uint8/... in {0,1}.  Returns LUT (C, 2^k, R) uint32 where
    C = R = n//k and LUT[c, p, r] = A_tile[r, c] @ b_p over GF(2), packed as a
    k-bit word (bit j = row j of the tile-product).
    """
    n = a_bits.shape[0]
    assert a_bits.shape == (n, n) and n % k == 0
    nk = n // k
    tiles = a_bits.reshape(nk, k, nk, k).transpose(0, 2, 1, 3).astype(jnp.uint32)  # (R, C, k, k)
    # all 2^k input vectors b_p: bit i of p = entry i of b_p
    p = jnp.arange(2 ** k, dtype=jnp.uint32)
    bvec = (p[:, None] >> jnp.arange(k, dtype=jnp.uint32)[None, :]) & 1  # (2^k, k)
    # product bits: tiles (R,C,k_out,k_in) x bvec (P,k_in) -> parity over k_in
    prod = jnp.einsum("rcoi,pi->rcpo", tiles, bvec) % 2                   # (R, C, P, k)
    words = (prod << jnp.arange(k, dtype=jnp.uint32)[None, None, None, :]).sum(-1)
    return words.transpose(1, 2, 0).astype(jnp.uint32)                    # (C, P, R)


def gf2_pack_vector(v_bits: jax.Array, k: int) -> jax.Array:
    """(..., n) bits -> (..., n//k) k-bit uint32 words (LUT partition indices)."""
    *lead, n = v_bits.shape
    w = v_bits.reshape(*lead, n // k, k).astype(jnp.uint32)
    return (w << jnp.arange(k, dtype=jnp.uint32)).sum(-1)


def gf2_unpack_vector(words: jax.Array, k: int) -> jax.Array:
    """inverse of gf2_pack_vector."""
    bits = (words[..., None] >> jnp.arange(k, dtype=jnp.uint32)) & 1
    return bits.reshape(*words.shape[:-1], words.shape[-1] * k).astype(jnp.uint8)


def gf2_bmvm(lut: jax.Array, v_words: jax.Array) -> jax.Array:
    """Compute A@v over GF(2) from the LUT.  v_words: (M, C) -> (M, R).

    out[m, r] = XOR_c LUT[c, v_words[m, c], r]  — each processing node c looks
    up partition v_c and the XOR-accumulate happens at node r (paper §VI-A).
    """
    C, P, R = lut.shape
    looked = jax.vmap(lambda vw: lut[jnp.arange(C), vw, :], in_axes=0)(v_words)  # (M, C, R)
    acc = looked[:, 0, :]
    for c in range(1, C):
        acc = jnp.bitwise_xor(acc, looked[:, c, :])
    return acc


def gf2_matmul_oracle(a_bits: jax.Array, v_bits: jax.Array) -> jax.Array:
    """Direct O(n^2) GF(2) mat-vec: (n,n) x (M,n) -> (M,n)."""
    return (v_bits.astype(jnp.uint32) @ a_bits.astype(jnp.uint32).T) % 2


# ---------------------------------------------------------------------------
# LDPC min-sum check-node update (paper §IV)
# ---------------------------------------------------------------------------

def minsum_check(u: jax.Array) -> jax.Array:
    """Check-node processing with the two-min trick.

    u: (n_checks, deg) incoming LLRs.  out[c, j] = prod_{i≠j} sign(u_i) *
    min_{i≠j} |u_i|.  (The paper's Listing 2 is the sign-free 3-input variant;
    this is the standard general form — reduces to it for positive inputs.)
    """
    mag = jnp.abs(u)
    sgn = jnp.where(u < 0, -1.0, 1.0).astype(u.dtype)
    total_sign = jnp.prod(sgn, axis=-1, keepdims=True)
    min1 = jnp.min(mag, axis=-1, keepdims=True)
    amin = jnp.argmin(mag, axis=-1)
    masked = jnp.where(jax.nn.one_hot(amin, u.shape[-1], dtype=bool), jnp.inf, mag)
    min2 = jnp.min(masked, axis=-1, keepdims=True)
    is_min = jax.nn.one_hot(amin, u.shape[-1], dtype=bool)
    mins = jnp.where(is_min, min2, min1)
    return (total_sign * sgn) * mins  # sign excluding self; |.| excluding self


def bitnode_sum(u0: jax.Array, v: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Bit-node processing (paper Listing 3): total = u0 + Σv;  u_j = total - v_j."""
    total = u0 + jnp.sum(v, axis=-1)
    return total, total[..., None] - v


# ---------------------------------------------------------------------------
# Particle filter: weighted histogram + Bhattacharyya (paper §V)
# ---------------------------------------------------------------------------

def weighted_histogram(bins: jax.Array, weights: jax.Array, n_bins: int) -> jax.Array:
    """bins: (N, px) int32 bin index per pixel; weights: (px,) distance
    weights.  -> (N, n_bins) normalized weighted histograms."""
    onehot = jax.nn.one_hot(bins, n_bins, dtype=weights.dtype)      # (N, px, B)
    hist = jnp.einsum("npb,p->nb", onehot, weights)
    return hist / jnp.maximum(hist.sum(-1, keepdims=True), 1e-12)


def bhattacharyya(hist: jax.Array, ref_hist: jax.Array) -> jax.Array:
    """(N, B), (B,) -> (N,) Bhattacharyya coefficients."""
    return jnp.sum(jnp.sqrt(hist * ref_hist[None, :]), axis=-1)


def particle_weights(bins: jax.Array, weights: jax.Array, ref_hist: jax.Array,
                     sigma: float = 0.1) -> jax.Array:
    """Full PE of paper Fig. 11: histogram -> BC -> weight = exp((BC-1)/σ²)."""
    hist = weighted_histogram(bins, weights, ref_hist.shape[-1])
    bc = bhattacharyya(hist, ref_hist)
    w = jnp.exp((bc - 1.0) / (sigma * sigma))
    return w / jnp.maximum(w.sum(), 1e-12)


# ---------------------------------------------------------------------------
# Flash attention (forward) — LM-stack hot spot
# ---------------------------------------------------------------------------

def mha(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
        scale: float | None = None) -> jax.Array:
    """q: (B, Hq, S, D), k/v: (B, Hkv, T, D) with Hq % Hkv == 0 (GQA)."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    qg = q.reshape(B, Hkv, g, S, D)
    scale = scale if scale is not None else D ** -0.5
    logits = jnp.einsum("bhgsd,bhtd->bhgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        S_, T_ = logits.shape[-2], logits.shape[-1]
        mask = jnp.tril(jnp.ones((S_, T_), bool), k=T_ - S_)
        logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bhgsd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, S, D).astype(q.dtype)
