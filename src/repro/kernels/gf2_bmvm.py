"""Pallas TPU kernel for Williams' sub-quadratic GF(2) BMVM (paper §VI).

FPGA→TPU adaptation: the paper maps the precomputed LUTs to BRAM and
XOR-accumulates incoming k-bit flits at each processing node.  Here each grid
step c streams one column-tile's LUT slab HBM→VMEM, the packed sub-vector
word ``v[m, c]`` (scalar-prefetched to SMEM — the "partition index" flit)
selects one of the 2^k LUT rows, and the XOR accumulation happens in the
revisited VMEM output block — the VPU-resident restatement of the BRAM-lookup
+ XOR-tree datapath.

Layout: LUT (C, 2^k, R) uint32, R padded to a multiple of 128 (lane dim);
the 2^k axis is the sublane axis.  Grid = (M_blocks, C); output block
(BM, R) is revisited across the C axis (reduction pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(v_ref, lut_ref, out_ref, *, bm: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    m0 = pl.program_id(0) * bm
    # lut_ref block: (1, 2^k, R); select the partition row per batch element
    # (the flit "partition index" v[m, c]) and XOR into the accumulator.
    for dm in range(bm):  # bm is small & static; unrolled gather over sublanes
        idx = v_ref[m0 + dm, c]
        row = lut_ref[0, idx, :]
        out_ref[dm, :] = jnp.bitwise_xor(out_ref[dm, :], row)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def gf2_bmvm_pallas(lut: jax.Array, v_words: jax.Array, *, bm: int = 8,
                    interpret: bool = True) -> jax.Array:
    """lut: (C, P=2^k, R) uint32;  v_words: (M, C) uint32 -> (M, R) uint32."""
    C, P, R = lut.shape
    M = v_words.shape[0]
    assert v_words.shape == (M, C)
    pad_m = (-M) % bm
    if pad_m:
        v_words = jnp.concatenate([v_words, jnp.zeros((pad_m, C), v_words.dtype)])
    Mp = M + pad_m
    grid = (Mp // bm, C)
    out = pl.pallas_call(
        functools.partial(_kernel, bm=bm),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((1, P, R), lambda m, c, v: (c, 0, 0))],
            out_specs=pl.BlockSpec((bm, R), lambda m, c, v: (m, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, R), jnp.uint32),
        interpret=interpret,
    )(v_words.astype(jnp.int32), lut)
    return out[:M]
