"""Pallas TPU kernel: particle-filter weighted histogram + Bhattacharyya PE
(paper §V, Fig. 11 — the "candidate histogram" + "Bhattacharya distance"
compute element).

FPGA→TPU adaptation: the FPGA PE walks pixels sequentially into BRAM bins.
A serial scatter wastes the VPU/MXU, so the kernel restates binning as a
one-hot matmul: for a pixel block, ``onehot(bins) @ diag(weights)`` summed
over pixels — an (px_block × n_bins) MXU contraction.  Grid =
(particle_blocks, pixel_blocks) with the histogram block revisited across the
pixel axis (reduction), then the Bhattacharyya coefficient reduces the final
histogram against the reference in the same kernel (fused epilogue).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(bins_ref, w_ref, ref_ref, hist_ref, bc_ref, *, n_bins: int, n_px: int, bpx: int):
    p = pl.program_id(1)
    n_px_blocks = pl.num_programs(1)

    @pl.when(p == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    b = bins_ref[...]                                   # (BN, BPX) int32
    w = w_ref[...]                                      # (1, BPX) f32
    onehot = (b[:, :, None] == jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_bins), 2))
    # mask out pixel padding in the last block
    px0 = p * bpx
    valid = (px0 + jax.lax.broadcasted_iota(jnp.int32, (1, b.shape[1], 1), 1)) < n_px
    contrib = jnp.where(onehot & valid, w[0][None, :, None], 0.0)
    hist_ref[...] += jnp.sum(contrib, axis=1)           # (BN, n_bins)

    @pl.when(p == n_px_blocks - 1)
    def _epilogue():
        h = hist_ref[...]
        h = h / jnp.maximum(jnp.sum(h, axis=-1, keepdims=True), 1e-12)
        hist_ref[...] = h
        bc_ref[...] = jnp.sum(jnp.sqrt(h * ref_ref[...]), axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("n_bins", "bn", "bpx", "interpret"))
def particle_histogram_pallas(bins: jax.Array, weights: jax.Array, ref_hist: jax.Array,
                              *, n_bins: int, bn: int = 8, bpx: int = 512,
                              interpret: bool = True):
    """bins: (N, px) int32; weights: (px,); ref_hist: (n_bins,)
    -> (hist (N, n_bins), bc (N,))."""
    N, px = bins.shape
    bn = min(bn, N)
    bpx = min(bpx, px)
    pad_n = (-N) % bn
    pad_p = (-px) % bpx
    bins_p = jnp.pad(bins, ((0, pad_n), (0, pad_p)))
    w_p = jnp.pad(weights, (0, pad_p))[None, :]
    grid = ((N + pad_n) // bn, (px + pad_p) // bpx)
    hist, bc = pl.pallas_call(
        functools.partial(_kernel, n_bins=n_bins, n_px=px, bpx=bpx),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bpx), lambda i, p: (i, p)),
            pl.BlockSpec((1, bpx), lambda i, p: (0, p)),
            pl.BlockSpec((1, n_bins), lambda i, p: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, n_bins), lambda i, p: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, p: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N + pad_n, n_bins), jnp.float32),
            jax.ShapeDtypeStruct((N + pad_n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(bins_p, w_p, ref_hist[None, :].astype(jnp.float32))
    return hist[:N], bc[:N, 0]
