"""Pallas TPU kernel: LDPC min-sum check-node update (paper §IV, Fig. 7).

The FPGA check node is a compare tree over the incoming bit-node messages.
On TPU the natural unit is a *block of check nodes*: block (BC, deg) of LLRs
in VMEM, two-min trick computed with VPU reductions along the lane axis, all
checks in the block updated in one shot.  Grid = (n_checks / BC,).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(u_ref, out_ref):
    u = u_ref[...]
    mag = jnp.abs(u)
    sgn = jnp.where(u < 0, -1.0, 1.0).astype(u.dtype)
    total_sign = jnp.prod(sgn, axis=-1, keepdims=True)
    min1 = jnp.min(mag, axis=-1, keepdims=True)
    amin = jnp.argmin(mag, axis=-1)
    is_min = jax.lax.broadcasted_iota(jnp.int32, mag.shape, 1) == amin[:, None]
    min2 = jnp.min(jnp.where(is_min, jnp.inf, mag), axis=-1, keepdims=True)
    mins = jnp.where(is_min, min2, min1)
    out_ref[...] = (total_sign * sgn) * mins


@functools.partial(jax.jit, static_argnames=("bc", "interpret"))
def minsum_check_pallas(u: jax.Array, *, bc: int = 256, interpret: bool = True) -> jax.Array:
    """u: (n_checks, deg) f32 -> (n_checks, deg) check-to-bit messages."""
    n, deg = u.shape
    bc = min(bc, n)
    pad = (-n) % bc
    if pad:
        u = jnp.concatenate([u, jnp.ones((pad, deg), u.dtype)])
    out = pl.pallas_call(
        _kernel,
        grid=((n + pad) // bc,),
        in_specs=[pl.BlockSpec((bc, deg), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bc, deg), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, deg), u.dtype),
        interpret=interpret,
    )(u)
    return out[:n]
