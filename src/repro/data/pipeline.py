"""Deterministic, resumable, sharded token pipeline.

Production posture without a corpus dependency: batches are synthesized from
a counter-based PRNG keyed by ``(seed, step, shard)``, which gives the three
properties a 1000-node trainer actually needs from its input layer:

* **determinism / restart-exactness** — batch(step) is a pure function; a job
  restarted from a checkpoint at step k sees byte-identical data from step k,
  no iterator state to persist beyond the step counter (tested).
* **shard disjointness** — shard i of `n_shards` derives from a distinct key;
  elastic re-sharding (n_shards changes) stays deterministic per (step, i).
* **zero coordination** — any host can synthesize any shard: a restarted or
  migrated host never replays or skips (the straggler/restart story).

A background prefetch thread keeps `prefetch` batches ahead (double
buffering), mirroring a real corpus reader.  Swap `_synthesize` for a real
tokenized shard reader and the contract is unchanged.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_shards: int = 1
    shard: int = 0
    seed: int = 0
    with_labels: bool = True
    prefetch: int = 2

    @property
    def shard_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards


def _synthesize(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """Markov-ish synthetic tokens (not uniform noise, so loss can fall)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.shard, cfg.n_shards]))
    B, S, V = cfg.shard_batch, cfg.seq_len, cfg.vocab
    base = rng.integers(0, V, (B, 1), dtype=np.int32)
    drift = rng.integers(-8, 9, (B, S), dtype=np.int32).cumsum(axis=1)
    toks = (base + np.abs(drift)) % V
    out = {"tokens": toks.astype(np.int32)}
    if cfg.with_labels:
        nxt = np.roll(toks, -1, axis=1)
        nxt[:, -1] = -1  # ignore last position
        out["labels"] = nxt.astype(np.int32)
    return out


class ShardedTokenPipeline:
    """Iterator with explicit step state (checkpointable as a single int)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=max(cfg.prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            batch = _synthesize(self.cfg, s)
            while not self._stop.is_set():
                try:
                    self._q.put((s, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        s, batch = self._q.get()
        # guard against a stale prefetch after restore(); resync if needed
        while s != self.step:
            s, batch = self._q.get()
        self.step += 1
        return batch

    def peek_step(self) -> int:
        return self.step

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict) -> "ShardedTokenPipeline":
        self.close()
        return ShardedTokenPipeline(self.cfg, start_step=int(state["step"]))

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Random access (the restart-exactness contract)."""
        return _synthesize(self.cfg, step)

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=1.0)
