from .pipeline import DataConfig, ShardedTokenPipeline
