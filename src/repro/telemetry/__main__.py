"""Traced case-study runs: ``python -m repro.telemetry``.

Runs any of the three paper apps (BMVM / LDPC / particle filter) on any
topology in any simulated mode with a tracer attached, checks the
trace↔stats parity contract, and dumps the Perfetto JSON timeline plus the
link-utilization report.

    python -m repro.telemetry --app bmvm --topology mesh --out trace.json
    python -m repro.telemetry --app ldpc --topology torus --mode buffered
    python -m repro.telemetry --app pf --pods --csv
    python -m repro.telemetry --app bmvm --mode buffered --profile

``--profile`` additionally runs the latency profiler (exact per-packet
decomposition + critical path + gap attribution; `repro.telemetry.profile`)
and prints the bottleneck report; with ``--metrics`` the per-flow
``noc.latency.*`` histograms land in the snapshot too.
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def _pods(n_nodes: int) -> list[int]:
    return [0] * (n_nodes // 2) + [1] * (n_nodes - n_nodes // 2)


def _run_app(app: str, topology: str, mode: str, iters: int, pods: bool,
             tracer):
    rng = np.random.default_rng(0)
    if app == "bmvm":
        from ..apps import bmvm
        cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)
        A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
        v = rng.integers(0, 2, (64,)).astype(np.uint8)
        lut = bmvm.preprocess(A, cfg)
        n = 2 * cfg.n_pe
        _, stats = bmvm.iterate_noc_sim(
            lut, v, cfg, iters, topology=topology, mode=mode,
            pods=_pods(n) if pods else None, tracer=tracer)
    elif app == "ldpc":
        from ..apps import ldpc
        H = ldpc.fano_plane_H()
        llr = ldpc.awgn_llr(np.zeros(7, np.int8), 4.0, rng)
        _, _, stats = ldpc.decode_on_noc(
            H, llr, iters, topology=topology, n_nodes=16, mode=mode,
            pods=_pods(16) if pods else None, tracer=tracer)
    else:   # pf
        from ..apps import particle_filter as pf
        cfg = pf.PFConfig(img=48, roi=12, n_particles=32, n_bins=12)
        frames, _ = pf.synth_video(cfg, iters + 1, rng)
        _, stats = pf.track_on_noc(
            frames, cfg, n_pe=4, topology=topology, n_nodes=8, mode=mode,
            pods=_pods(8) if pods else None, tracer=tracer)
    return stats


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry",
        description="traced case-study run -> Perfetto JSON + link report")
    ap.add_argument("--app", choices=("bmvm", "ldpc", "pf"), default="bmvm")
    ap.add_argument("--topology",
                    choices=("ring", "mesh", "torus", "fattree"),
                    default="mesh")
    ap.add_argument("--mode", choices=("sim", "sim_python", "buffered"),
                    default="sim")
    ap.add_argument("--iters", type=int, default=3,
                    help="iterations (bmvm/ldpc) or tracked frames (pf)")
    ap.add_argument("--pods", action="store_true",
                    help="partition over 2 pods (quasi-SERDES bridges)")
    ap.add_argument("--capacity", type=int, default=1 << 20,
                    help="tracer ring-buffer capacity (events)")
    ap.add_argument("--detail", choices=("cycles", "flits"),
                    default="cycles",
                    help="'flits' records every switch flit move")
    ap.add_argument("--out", default=None,
                    help="write the Perfetto/Chrome trace JSON here")
    ap.add_argument("--csv", action="store_true",
                    help="emit the link report as CSV instead of a matrix")
    ap.add_argument("--metrics", default=None,
                    help="enable the metrics registry; write snapshot here")
    ap.add_argument("--profile", action="store_true",
                    help="print the latency profiler's bottleneck report "
                         "(and publish noc.latency.* when --metrics)")
    args = ap.parse_args(argv)

    from .export import (chrome_trace, heatmap, link_utilization,
                         write_chrome_trace)
    from .metrics import disable_metrics, enable_metrics
    from .profile import profile_trace
    from .tracer import Tracer, trace_stats

    reg = enable_metrics() if args.metrics else None
    tr = Tracer(capacity=args.capacity, detail=args.detail)
    stats = _run_app(args.app, args.topology, args.mode, args.iters,
                     args.pods, tr)
    agg = trace_stats(tr)
    ok = agg.as_dict() == stats.as_dict()
    print(f"{args.app} on {args.topology} ({args.mode}"
          f"{', 2 pods' if args.pods else ''}): {len(tr.events())} events, "
          f"parity {'OK (bit-exact)' if ok else 'FAILED'}")
    if not ok:
        raise SystemExit("trace does not reproduce NoCStats:\n"
                         f"  engine: {stats.as_dict()}\n"
                         f"  trace:  {agg.as_dict()}")
    for k, v in stats.as_dict().items():
        if v:
            print(f"  {k:>24} {v}")
    if args.out:
        doc = chrome_trace(tr)
        write_chrome_trace(args.out, doc)
        print(f"Perfetto trace -> {args.out} ({len(doc['traceEvents'])} "
              f"events; load in ui.perfetto.dev)")
    print()
    print(heatmap(link_utilization(tr), csv=args.csv))
    if args.profile:
        prof = profile_trace(tr).check_exact()
        if reg is not None:
            prof.publish(reg, app=args.app, topology=args.topology,
                         mode=args.mode)
        print()
        print(prof.report())
    if reg is not None:
        with open(args.metrics, "w") as fh:
            json.dump(reg.snapshot(), fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"metrics snapshot -> {args.metrics}")
        disable_metrics()


if __name__ == "__main__":
    main()
