"""Event tracer: bounded ring buffer + the trace→NoCStats aggregation.

The event taxonomy (the telemetry contract — aggregation and exporters key
on ``name``; ``track`` names the Perfetto timeline row):

=============  =======  ===================  ======================================
name           kind     track                args / value
=============  =======  ===================  ======================================
run            instant  "noc"                mode, topology, n_nodes, batch
wave           span     "noc"                wave, msgs; dur = scatter+route+gather
scatter        span     "engine"             msgs, bytes
route          span     "engine"             mode
gather         span     "engine"             —
msg            instant  "node {src}"         src, dst, bytes, flits, hops, n
                                             [+ wire_bytes, beats when cross-pod]
round          instant  "noc"                bytes, links (one per schedule round)
link           counter  "link {s}->{d}"      value = bytes this round (schedule
                                             modes) or flit-bytes this switch
                                             run (buffered mode, one per link)
cycle          instant  "switch"             c, moves, bytes, stalls, arb, ejects
queue          counter  "switch queue"       value = peak FIFO occupancy, cycle
flit           instant  "router {u}"         pid, f, vc, to (detail="flits" only)
switch_run     instant  "switch"             packets, flits, bound (analytic
                                             switch_lower_bound for the run)
pkt            instant  "node {dst}"         pid, src, dst, flits, hops, inject,
                                             lat, stall, arb (one per packet,
                                             emitted at tail ejection)
idle_ff        instant  "switch"             to (cycle-counter fast-forward)
deadlock       instant  "switch"             wedged, wait_cycle
bridge_cfg     instant  "bridges"            n, wire_bits, lanes, beat_bytes, ...
bridge_tx      instant  "bridge {s}->{d}"    words, beats, wire_bytes
bridge_fifo    counter  "bridge {s}->{d}"    value = FIFO occupancy, wire words
bridge_stall   instant  "bridges"            rounds, src, dst (the gating bridge)
=============  =======  ===================  ======================================

Timestamps are *logical* NoC time: each wave occupies ``[t0, t0 + dur)``
where scatter takes 1 tick, the route phase takes its rounds (or switch
cycles, plus bridge stall rounds) and gather takes 1 tick.  The engines
advance ``Tracer.clock`` accordingly, so one trace covers a whole
``run_iterative``/``run_batch`` timeline.

The correctness contract (the whole point): :func:`trace_stats` folds a full
trace back into a `repro.core.noc.NoCStats` that is **bit-exact** against
what the engine returned — sums for the flow counters, maxes for the
high-water marks, switch cycles recovered from the per-cycle events.  The
trace is a proof-carrying account of the run, not a best-effort log; the
parity is differential-tested across the topology × app × mode grid in
``tests/test_telemetry.py``.

The buffer is bounded (``capacity`` events, oldest dropped first) so tracing
can never blow up memory on a runaway workload; :func:`trace_stats` refuses
to aggregate a trace that dropped events (a partial trace proves nothing).

:mod:`repro.telemetry.profile` consumes the same stream and rebuilds
per-packet/per-message latency records with an exact component decomposition
and per-wave gap attribution; ``docs/observability.md`` documents the whole
contract end to end.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable, Optional, Union

# module-wide allocation counter: the zero-overhead-when-off property is
# tested as "this number does not move when tracing is disabled"
_N_EVENTS = 0


def events_allocated() -> int:
    """Total TraceEvents allocated in this process (test/debug hook)."""
    return _N_EVENTS


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One structured event.  ``kind``: 'span' | 'instant' | 'counter'."""

    ts: int
    name: str
    track: str
    kind: str = "instant"
    dur: int = 0
    value: float = 0.0
    args: Optional[dict] = None


class Tracer:
    """Bounded ring buffer of :class:`TraceEvent`.

    ``capacity`` — max events retained (oldest evicted first; ``dropped``
    counts evictions).  ``detail`` — '"cycles"'' (default) keeps per-cycle
    aggregates; ``"flits"`` additionally records every flit move through the
    wormhole switch (one event per flit per hop — verbose, post-mortem use).

    ``clock`` is the logical timebase the engines advance between waves;
    emit helpers default ``ts`` to it.
    """

    def __init__(self, capacity: int = 1 << 20, detail: str = "cycles"):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if detail not in ("cycles", "flits"):
            raise ValueError(f"detail must be 'cycles' or 'flits', got {detail!r}")
        self.capacity = capacity
        self.detail = detail
        self._buf: deque[TraceEvent] = deque(maxlen=capacity)
        self.emitted = 0
        self.clock = 0

    # -- emission ----------------------------------------------------------
    def _push(self, ev: TraceEvent) -> None:
        global _N_EVENTS
        _N_EVENTS += 1
        self.emitted += 1
        self._buf.append(ev)

    def instant(self, name: str, track: str, ts: Optional[int] = None,
                **args) -> None:
        self._push(TraceEvent(self.clock if ts is None else ts, name, track,
                              "instant", args=args or None))

    def span(self, name: str, track: str, ts: int, dur: int, **args) -> None:
        self._push(TraceEvent(ts, name, track, "span", dur=dur,
                              args=args or None))

    def counter(self, name: str, track: str, value: float,
                ts: Optional[int] = None) -> None:
        self._push(TraceEvent(self.clock if ts is None else ts, name, track,
                              "counter", value=value))

    # -- access ------------------------------------------------------------
    @property
    def dropped(self) -> int:
        """Events evicted by the ring bound (0 ⇔ the trace is complete)."""
        return self.emitted - len(self._buf)

    def events(self) -> list[TraceEvent]:
        return list(self._buf)

    def clear(self) -> None:
        self._buf.clear()
        self.emitted = 0
        self.clock = 0

    def __len__(self) -> int:
        return len(self._buf)


# ---------------------------------------------------------------------------
# aggregation: trace -> NoCStats, bit-exact
# ---------------------------------------------------------------------------

def trace_stats(trace: Union[Tracer, Iterable[TraceEvent]], *,
                strict: bool = True):
    """Fold a complete trace into a `repro.core.noc.NoCStats`.

    Every counter is rebuilt from first-principles events — per-message
    ``msg`` events for payload/flit/cross-pod counters, per-round ``round``
    events for schedule rounds/link bytes, per-cycle ``cycle``/``queue``
    events for the buffered switch (cycles are recovered as ``max c + 1``
    per switch run; a ``c`` that does not increase starts a new run), and
    the ``bridge_*`` events for the serial links.  High-water marks merge by
    max, flows by sum — exactly `NoCStats.add` semantics — so the result is
    bit-identical to the engine's own accounting (differential-tested).

    ``strict=True`` (default) raises if the tracer dropped events: an
    incomplete trace cannot prove anything about the run.
    """
    from ..core.noc import NoCStats

    if isinstance(trace, Tracer):
        if strict and trace.dropped:
            raise ValueError(
                f"trace dropped {trace.dropped} events (capacity="
                f"{trace.capacity}): aggregation of a partial trace would "
                f"not reproduce NoCStats; raise the Tracer capacity")
        events: Iterable[TraceEvent] = trace.events()
    else:
        events = list(trace)
    st = NoCStats()
    prev_c: Optional[int] = None   # last cycle index of the open switch run

    def commit_switch_run() -> None:
        nonlocal prev_c
        if prev_c is not None:
            # buffered transport: rounds ARE switch cycles (mode-specific
            # accounting of NoCExecutor._run_compiled)
            st.rounds += prev_c + 1
            st.switch_cycles += prev_c + 1
            prev_c = None

    for ev in events:
        name = ev.name
        if name == "wave":
            commit_switch_run()
            st.waves += 1
        elif name == "msg":
            a = ev.args or {}
            k = a.get("n", 1)
            st.payload_bytes += k * a["bytes"]
            st.flits += k * a["flits"]
            if "wire_bytes" in a:
                st.cross_pod_msgs += k
                st.cross_pod_wire_bytes += k * a["wire_bytes"]
                st.cross_pod_beats += k * a["beats"]
        elif name == "round":
            st.rounds += 1
            st.link_bytes += ev.args["bytes"]
        elif name == "cycle":
            a = ev.args
            c = a["c"]
            if prev_c is not None and c <= prev_c:
                st.rounds += prev_c + 1       # a new switch run started
                st.switch_cycles += prev_c + 1
            prev_c = c
            st.link_bytes += a["bytes"]
            st.switch_stall_cycles += a["stalls"]
            st.switch_arb_losses += a["arb"]
            st.switch_peak_link_flits = max(st.switch_peak_link_flits,
                                            a["moves"])
        elif name == "queue":
            st.switch_max_queue = max(st.switch_max_queue, int(ev.value))
        elif name == "bridge_tx":
            a = ev.args
            st.bridge_beats += a["beats"]
            st.bridge_wire_bytes += a["wire_bytes"]
        elif name == "bridge_stall":
            st.bridge_stall_rounds += ev.args["rounds"]
        elif name == "bridge_fifo":
            st.bridge_peak_fifo = max(st.bridge_peak_fifo, int(ev.value))
    commit_switch_run()
    return st
