"""Latency profiler: trace events → per-packet records, critical path, gaps.

`repro.telemetry.trace_stats` proves a trace reproduces the run's *totals*;
this module answers the next question — **where did the cycles go?**  It
consumes the same `Tracer` stream (nothing is re-simulated) and rebuilds:

* one :class:`LatencyRecord` per delivered packet (buffered transport,
  from ``pkt`` events) or per message (schedule transports, from ``msg``
  events), with the inject→eject latency on the logical clock decomposed
  into **serialization + hop + queueing + bridge** components that sum to
  the measured latency *bit-exactly* — the decomposition is an accounting
  identity, not an estimate (`Profile.check_exact` enforces it, and
  ``tests/test_profile.py`` differential-tests it across the topology ×
  app × mode grid);
* one :class:`WaveProfile` per wave with the analytic lower bound for that
  wave (`switch_lower_bound` via the ``switch_run`` event for the buffered
  switch, max hop distance for the schedule transports) and a **gap
  attribution**: every cycle above the bound is charged to a named
  resource — a hot link, arbitration losses at that link, credit stalls,
  or a saturated bridge (``bridge {s}->{d}``).  Attribution entries sum to
  the wave's gap exactly;
* the **critical path**: waves execute back-to-back on the logical clock,
  so the run's critical path chains each wave's slowest record; its length
  equals the final clock value, and on an uncontended single-packet run it
  collapses to ``latency == switch_lower_bound`` exactly (tested).

Decomposition semantics (documented in full in ``docs/observability.md``):

================  =====================================================
component         meaning
================  =====================================================
serialization     pure pipeline occupancy: ``n_flits`` tail cycles for a
                  wormhole packet; the scatter+gather ticks (2) for a
                  schedule message
hop               dimension-ordered hop distance src→dst (head traversal)
queueing          everything contention adds: credit stalls, arbitration
                  losses, schedule rounds beyond the hop distance —
                  computed as the exact remainder, so the identity
                  ``latency == ser + hop + queueing + bridge`` holds by
                  construction
bridge            stall rounds the quasi-SERDES bridges added to the
                  wave (schedule messages; buffered packets carry 0 —
                  the bridge overlay there is wave-level and appears in
                  the wave's gap attribution instead)
================  =====================================================

Zero-overhead-off mirrors the tracer contract: no ``LatencyRecord`` is
allocated unless :func:`profile_trace` is called (`records_allocated` is
the test hook, the analog of ``events_allocated``).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable, Optional, Union

from .tracer import TraceEvent, Tracer

# module-wide allocation counter: the zero-overhead-when-off property is
# tested as "this number does not move unless profile_trace runs"
_N_RECORDS = 0


def records_allocated() -> int:
    """Total LatencyRecords allocated in this process (test/debug hook)."""
    return _N_RECORDS


_LINK_TRACK = re.compile(r"^(link|bridge) (\d+)->(\d+)$")


@dataclasses.dataclass(frozen=True)
class LatencyRecord:
    """One delivered packet/message on the logical clock.

    ``kind`` — ``"pkt"`` (buffered wormhole packet) or ``"msg"`` (schedule
    message).  ``n`` — batch multiplicity (schedule messages carry the
    wave's batch factor; the latency is per item, the multiplicity scales
    the flow counts).  The component identity is checked by :attr:`exact`.
    """

    kind: str
    src: int
    dst: int
    t_inject: int
    t_eject: int
    flits: int
    hops: int
    serialization: int
    hop: int
    queueing: int
    bridge: int
    wave: int
    n: int = 1

    def __post_init__(self):
        global _N_RECORDS
        _N_RECORDS += 1

    @property
    def latency(self) -> int:
        return self.t_eject - self.t_inject

    @property
    def exact(self) -> bool:
        """The accounting identity: components sum to measured latency."""
        return (self.serialization + self.hop + self.queueing + self.bridge
                == self.latency)


@dataclasses.dataclass
class WaveProfile:
    """Per-wave accounting: duration, analytic bound, attributed gap.

    ``kind``: ``"switch"`` (buffered wave), ``"schedule"`` (sim/spmd wave),
    ``"switch_raw"`` (a bare `simulate_switch` run traced outside the
    executor — no wave span), ``"idle"`` (message-free wave).  ``rounds``
    is schedule rounds or switch cycles; ``gap`` is the cycles above
    ``bound`` plus bridge stalls, and ``attribution`` is a list of
    ``(resource, cycles)`` pairs summing to ``gap`` exactly.
    """

    index: int
    t0: int
    dur: int
    kind: str
    mode: str
    rounds: int
    bridge_stalls: int
    bound: int
    gap: int
    attribution: list
    stalls: int = 0
    arb: int = 0
    hot_link: Optional[str] = None
    n_records: int = 0


@dataclasses.dataclass(frozen=True)
class CriticalPath:
    """The longest dependency chain through the run.

    Waves are barriers on the logical clock, so the chain is one segment
    per wave — the wave's slowest element (max-latency packet/message, or
    the bare phase for idle waves).  ``length`` is the sum of wave
    durations == the final logical clock; ``gap`` and ``attribution`` are
    the merged above-bound accounting across all segments.
    """

    length: int
    segments: list
    gap: int
    attribution: list

    def __str__(self) -> str:
        steps = " -> ".join(s[1] for s in self.segments) or "(empty)"
        return f"critical path {self.length} ticks: {steps}"


@dataclasses.dataclass
class Profile:
    """The full profiler output for one trace (see module docstring)."""

    records: list
    waves: list
    links: dict
    modes: list

    # -- invariants --------------------------------------------------------
    def check_exact(self) -> "Profile":
        """Raise unless every record's decomposition sums exactly and every
        wave's attribution sums to its gap.  Returns self for chaining."""
        for r in self.records:
            if not r.exact:
                raise ValueError(
                    f"inexact decomposition for {r.kind} {r.src}->{r.dst} "
                    f"wave {r.wave}: ser={r.serialization} hop={r.hop} "
                    f"queue={r.queueing} bridge={r.bridge} != lat={r.latency}")
        for w in self.waves:
            attributed = sum(c for _, c in w.attribution)
            if attributed != w.gap:
                raise ValueError(
                    f"wave {w.index}: attribution sums to {attributed}, "
                    f"gap is {w.gap}")
        return self

    # -- critical path -----------------------------------------------------
    def critical_path(self) -> CriticalPath:
        segments, length, gap = [], 0, 0
        attr: dict = {}
        for w in self.waves:
            length += w.dur
            gap += w.gap
            for res, c in w.attribution:
                attr[res] = attr.get(res, 0) + c
            recs = [r for r in self.records if r.wave == w.index]
            if recs:
                worst = max(recs, key=lambda r: (r.latency, r.src, r.dst))
                desc = (f"wave {w.index} [{w.kind}] {worst.kind} "
                        f"{worst.src}->{worst.dst} lat={worst.latency}")
            else:
                desc = f"wave {w.index} [{w.kind}] dur={w.dur}"
            segments.append((w.index, desc, w.dur))
        merged = sorted(attr.items(), key=lambda kv: (-kv[1], kv[0]))
        return CriticalPath(length, segments, gap, merged)

    # -- flows -------------------------------------------------------------
    def flows(self) -> dict:
        """Per-(src, dst) latency stats from *exact sample quantiles* (the
        registry's `Histogram` is bucketed; this reads the raw records)."""
        by_flow: dict = {}
        for r in self.records:
            by_flow.setdefault((r.src, r.dst), []).extend([r.latency] * r.n)
        out = {}
        for flow, lats in sorted(by_flow.items()):
            lats.sort()
            k = len(lats)
            out[flow] = {
                "count": k,
                "p50": lats[max(0, -(-50 * k // 100) - 1)],
                "p99": lats[max(0, -(-99 * k // 100) - 1)],
                "p999": lats[max(0, -(-999 * k // 1000) - 1)],
                "max": lats[-1],
                "mean": sum(lats) / k,
            }
        return out

    # -- registry publication ---------------------------------------------
    def publish(self, registry=None, **labels) -> None:
        """Observe every record into ``noc.latency.*`` histograms.

        Schema (p50/p99/p99.9 via `Histogram.quantile`):

        * ``noc.latency.total`` — inject→eject latency
        * ``noc.latency.serialization`` / ``.hop`` / ``.queueing`` /
          ``.bridge`` — the components (same multiplicities, so component
          histogram sums equal the total histogram sum)
        * ``noc.latency.flow{flow="s->d"}`` — per-flow totals

        ``registry=None`` publishes into the process-wide registry if one
        is enabled, else is a no-op (the standard publisher guard).
        """
        if registry is None:
            from .metrics import get_registry

            registry = get_registry()
            if registry is None:
                return
        for r in self.records:
            for _ in range(r.n):
                registry.histogram("noc.latency.total", **labels).observe(r.latency)
                registry.histogram("noc.latency.serialization", **labels).observe(r.serialization)
                registry.histogram("noc.latency.hop", **labels).observe(r.hop)
                registry.histogram("noc.latency.queueing", **labels).observe(r.queueing)
                registry.histogram("noc.latency.bridge", **labels).observe(r.bridge)
                registry.histogram("noc.latency.flow",
                                   flow=f"{r.src}->{r.dst}", **labels).observe(r.latency)

    # -- human-readable bottleneck report ----------------------------------
    def report(self, top: int = 8) -> str:
        cp = self.critical_path()
        total = sum(r.latency * r.n for r in self.records)
        comp = {"serialization": 0, "hop": 0, "queueing": 0, "bridge": 0}
        for r in self.records:
            comp["serialization"] += r.serialization * r.n
            comp["hop"] += r.hop * r.n
            comp["queueing"] += r.queueing * r.n
            comp["bridge"] += r.bridge * r.n
        lines = ["bottleneck report",
                 "=" * 17,
                 f"modes: {', '.join(self.modes) or '(raw switch)'}   "
                 f"waves: {len(self.waves)}   records: "
                 f"{sum(r.n for r in self.records)}",
                 f"critical path: {cp.length} ticks over "
                 f"{len(cp.segments)} wave(s); gap above bounds: {cp.gap}",
                 "",
                 "latency decomposition (record-cycles, sums exactly):"]
        for k in ("serialization", "hop", "queueing", "bridge"):
            pct = 100.0 * comp[k] / total if total else 0.0
            lines.append(f"  {k:<14} {comp[k]:>10}  ({pct:5.1f}%)")
        lines.append(f"  {'total':<14} {total:>10}")
        lines.append("")
        lines.append("gap attribution (cycles above analytic bound):")
        if cp.attribution:
            for res, c in cp.attribution[:top]:
                lines.append(f"  {c:>8}  {res}")
        else:
            lines.append("  (none — the run met its lower bounds)")
        lines.append("")
        lines.append("flows (exact sample quantiles, top by p99):")
        flows = sorted(self.flows().items(),
                       key=lambda kv: (-kv[1]["p99"], kv[0]))
        for (s, d), st in flows[:top]:
            lines.append(f"  {s:>3}->{d:<3} n={st['count']:<6} "
                         f"p50={st['p50']:<6} p99={st['p99']:<6} "
                         f"p99.9={st['p999']:<6} max={st['max']}")
        hot = sorted(self.links.items(), key=lambda kv: (-kv[1], kv[0]))
        if hot:
            lines.append("")
            lines.append("hottest links (bytes):")
            for track, b in hot[:top]:
                lines.append(f"  {b:>10}  {track}")
        lines.append("")
        lines.append("critical path:")
        for _, desc, dur in cp.segments[:top]:
            lines.append(f"  +{dur:<5} {desc}")
        if len(cp.segments) > top:
            lines.append(f"  ... {len(cp.segments) - top} more wave(s)")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# the profiler proper: one pass over the event stream
# ---------------------------------------------------------------------------

class _WaveState:
    """Accumulates one wave's child events until its ``wave`` span lands."""

    __slots__ = ("msgs", "pkts", "n_rounds", "max_c", "stalls", "arb",
                 "sw_ts", "sw_bound", "bridge_stalls", "links")

    def __init__(self):
        self.msgs: list = []          # (ts, args) per msg instant
        self.pkts: list = []          # args per pkt instant
        self.n_rounds = 0
        self.max_c = -1
        self.stalls = 0
        self.arb = 0
        self.sw_ts: Optional[int] = None
        self.sw_bound = 0
        self.bridge_stalls: list = []  # (rounds, src, dst)
        self.links: dict = {}          # "link s->d" / "bridge s->d" -> bytes

    @property
    def pending(self) -> bool:
        return bool(self.msgs or self.pkts or self.n_rounds or
                    self.max_c >= 0 or self.bridge_stalls or self.links)


def _hot_link(ws: _WaveState) -> Optional[str]:
    if not ws.links:
        return None
    return max(ws.links.items(), key=lambda kv: (kv[1], kv[0]))[0]


def _finalize_wave(prof: Profile, ws: _WaveState, index: int, t0: int,
                   dur: int, mode: str, kind: str) -> None:
    """Turn one wave's accumulated events into records + a WaveProfile.

    The component arithmetic here IS the decomposition contract — every
    branch constructs the components so they sum to the measured latency
    identically (see module docstring); `Profile.check_exact` re-verifies.
    """
    hot = _hot_link(ws)
    for track, b in ws.links.items():
        prof.links[track] = prof.links.get(track, 0) + b
    bridge_rounds = sum(r for r, _, _ in ws.bridge_stalls)
    attribution: list = [(f"bridge {s}->{d}", r)
                         for r, s, d in ws.bridge_stalls if r]

    if ws.pkts:  # buffered switch wave (or raw switch run)
        base = ws.sw_ts if ws.sw_ts is not None else t0 + 1
        cycles = ws.max_c + 1
        if kind == "switch_raw":
            dur = cycles
        for a in ws.pkts:
            lat = a["lat"]
            prof.records.append(LatencyRecord(
                kind="pkt", src=a["src"], dst=a["dst"],
                t_inject=base + a["inject"],
                t_eject=base + a["inject"] + lat,
                flits=a["flits"], hops=a["hops"],
                serialization=a["flits"], hop=a["hops"],
                queueing=lat - a["flits"] - a["hops"], bridge=0,
                wave=index))
        sgap = max(0, cycles - ws.sw_bound) if ws.sw_ts is not None else 0
        if sgap:
            at = hot or "switch"
            contended = ws.stalls + ws.arb
            if contended:
                arb_share = min(sgap, round(sgap * ws.arb / contended))
                stall_share = sgap - arb_share
                if stall_share:
                    attribution.append((f"credit stall @ {at}", stall_share))
                if arb_share:
                    attribution.append((f"arbitration @ {at}", arb_share))
            else:
                attribution.append((f"serialization @ {at}", sgap))
        prof.waves.append(WaveProfile(
            index=index, t0=t0, dur=dur, kind=kind, mode=mode,
            rounds=cycles, bridge_stalls=bridge_rounds,
            bound=ws.sw_bound, gap=sgap + bridge_rounds,
            attribution=attribution, stalls=ws.stalls, arb=ws.arb,
            hot_link=hot, n_records=len(ws.pkts)))
    elif ws.msgs:  # schedule wave: every message spans the whole wave
        rounds = ws.n_rounds
        stall = dur - 2 - rounds   # bridge stalls stretch the route phase
        max_hops = 0
        for ts, a in ws.msgs:
            h = a.get("hops", 0)
            max_hops = max(max_hops, h)
            prof.records.append(LatencyRecord(
                kind="msg", src=a["src"], dst=a["dst"],
                t_inject=ts, t_eject=ts + dur,
                flits=a["flits"], hops=h,
                serialization=2, hop=h, queueing=rounds - h, bridge=stall,
                wave=index, n=a.get("n", 1)))
        sgap = max(0, rounds - max_hops)
        if sgap:
            attribution.append((
                f"schedule serialization @ {hot or 'schedule'}", sgap))
        prof.waves.append(WaveProfile(
            index=index, t0=t0, dur=dur, kind=kind, mode=mode,
            rounds=rounds, bridge_stalls=bridge_rounds, bound=max_hops,
            gap=sgap + bridge_rounds, attribution=attribution,
            hot_link=hot, n_records=len(ws.msgs)))
    else:  # message-free wave: scatter+gather barrier only
        prof.waves.append(WaveProfile(
            index=index, t0=t0, dur=dur, kind="idle", mode=mode,
            rounds=0, bridge_stalls=bridge_rounds, bound=0,
            gap=bridge_rounds, attribution=attribution, hot_link=None))


def profile_trace(trace: Union[Tracer, Iterable[TraceEvent]], *,
                  strict: bool = True) -> Profile:
    """Rebuild a :class:`Profile` from a complete trace.

    Single pass, same strictness contract as `trace_stats`: with
    ``strict=True`` (default) a `Tracer` that dropped events is refused —
    a partial trace cannot support latency claims.  ``strict=False``
    profiles whatever events remain (counts degrade predictably; the
    exactness invariant still holds for every record that IS rebuilt,
    since each record derives from a single event).

    Accepts a `Tracer` or any iterable of `TraceEvent` (e.g. the output of
    `repro.telemetry.export.events_from_chrome`, so saved Perfetto JSON
    round-trips back into a profile).
    """
    if isinstance(trace, Tracer):
        if strict and trace.dropped:
            raise ValueError(
                f"trace dropped {trace.dropped} events (capacity="
                f"{trace.capacity}): a partial trace cannot support "
                f"latency attribution; raise the Tracer capacity")
        events: Iterable[TraceEvent] = trace.events()
    else:
        events = list(trace)

    prof = Profile(records=[], waves=[], links={}, modes=[])
    ws = _WaveState()
    mode = "?"
    wave_i = 0
    for ev in events:
        name = ev.name
        if name == "run":
            m = (ev.args or {}).get("mode", "?")
            mode = m
            if m not in prof.modes:
                prof.modes.append(m)
        elif name == "msg":
            ws.msgs.append((ev.ts, ev.args or {}))
        elif name == "pkt":
            ws.pkts.append(ev.args)
        elif name == "round":
            ws.n_rounds += 1
        elif name == "cycle":
            c = ev.args["c"]
            if c > ws.max_c:
                ws.max_c = c
            ws.stalls += ev.args["stalls"]
            ws.arb += ev.args["arb"]
        elif name == "switch_run":
            if ws.pkts:   # back-to-back raw runs without wave spans
                _finalize_wave(prof, ws, wave_i,
                               ws.sw_ts if ws.sw_ts is not None else ev.ts,
                               0, mode, "switch_raw")
                wave_i += 1
                ws = _WaveState()
            ws.sw_ts = ev.ts
            ws.sw_bound = ev.args.get("bound", 0)
        elif name == "bridge_stall":
            a = ev.args
            ws.bridge_stalls.append((a["rounds"], a.get("src", -1),
                                     a.get("dst", -1)))
        elif name == "bridge_tx":
            # bridge byte-load joins the link tally so the hot resource of
            # a partitioned wave can be a bridge, not just a router link
            ws.links[ev.track] = ws.links.get(ev.track, 0) \
                + ev.args["wire_bytes"]
        elif name == "link":
            m = _LINK_TRACK.match(ev.track)
            if m:
                ws.links[ev.track] = ws.links.get(ev.track, 0) + int(ev.value)
        elif name == "wave":
            _finalize_wave(prof, ws, wave_i, ev.ts, ev.dur, mode,
                           "switch" if ws.pkts else
                           ("schedule" if ws.msgs else "idle"))
            wave_i += 1
            ws = _WaveState()
    if ws.pending:   # trailing raw switch run (no executor wave span)
        _finalize_wave(prof, ws, wave_i,
                       ws.sw_ts if ws.sw_ts is not None else 0, 0, mode,
                       "switch_raw" if ws.pkts else "schedule")
    return prof
