"""Unified metrics registry: counters, gauges, log-bucketed histograms.

One naming scheme for every engine.  NoC engines publish their `NoCStats`
under ``noc.*`` (flow counters as Counters, high-water marks as max-Gauges),
MoE dispatch publishes ``noc.moe.*`` (`MoEDispatchStats.publish`), and the
train/serve loops time their steps into latency Histograms
(``train.step.seconds``, ``serve.prefill.seconds``, ``serve.decode.seconds``)
with p50/p99/p99.9 read straight off the log buckets.  The per-step metric
dict that `transformer.loss` returns maps onto the same names via
:data:`STEP_METRIC_NAMES` — no more parallel ad-hoc dicts.

The registry is opt-in and process-wide: :func:`enable_metrics` installs it,
:func:`get_registry` returns ``None`` when disabled (publishers guard on
that, so the off path is one pointer check).  Exposition: :meth:`snapshot`
(JSON-ready dict) and :meth:`prometheus` (text format, histograms as
summaries with quantiles).

Histograms bucket by powers of ``2**0.25`` (~19% relative width), so a
quantile estimate is exact to within one bucket and is clamped to the
observed min/max.  This module imports nothing from ``repro.core`` at
module scope — the engines import it, not the other way around.
"""
from __future__ import annotations

import contextlib
import math
import time
from typing import Optional

_LOG_GROWTH = 0.25 * math.log(2.0)   # log of the bucket growth factor

# transformer.loss step-metric dict keys -> canonical metric names.  The
# dict keys themselves are pinned by tests/test_moe_noc.py; the mapping is
# how they join the shared schema.
STEP_METRIC_NAMES = {
    "moe_drops": "noc.moe.drops",
    "moe_peak_occupancy": "noc.moe.peak_occupancy",
}

# MoEDispatchStats field -> canonical metric name (same names the step
# metrics above land on, so traces, dispatch stats and train metrics agree)
MOE_METRIC_NAMES = {
    "flits": "noc.moe.flits",
    "rounds": "noc.moe.rounds",
    "link_bytes": "noc.moe.link_bytes",
    "drops": "noc.moe.drops",
    "peak_occupancy": "noc.moe.peak_occupancy",
}


def _key(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic sum."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict):
        self.name, self.labels, self.value = name, labels, 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("Counter.inc amount must be >= 0")
        self.value += amount


class Gauge:
    """Last-write value; ``set_max`` for high-water marks."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict):
        self.name, self.labels, self.value = name, labels, 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def set_max(self, value: float) -> None:
        self.value = max(self.value, float(value))


class Histogram:
    """Log-bucketed histogram (growth 2**0.25) with quantile readout.

    Values ≤ 0 collapse into a dedicated underflow bucket.  ``quantile``
    returns the upper edge of the bucket holding the target rank, clamped
    to the observed [min, max] — exact to one bucket (~19%).
    """

    __slots__ = ("name", "labels", "buckets", "count", "total", "vmin", "vmax")
    GROWTH = 2 ** 0.25

    def __init__(self, name: str, labels: dict):
        self.name, self.labels = name, labels
        self.buckets: dict = {}   # bucket index (None = underflow) -> count
        self.count = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        self.vmin = v if self.vmin is None else min(self.vmin, v)
        self.vmax = v if self.vmax is None else max(self.vmax, v)
        idx = None if v <= 0.0 else math.ceil(math.log(v) / _LOG_GROWTH - 1e-9)
        self.buckets[idx] = self.buckets.get(idx, 0) + 1

    def quantile(self, q: float) -> float:
        """Quantile estimate, exact to one bucket (~19% relative).

        Contract (tested in ``tests/test_telemetry.py``):

        * empty histogram → ``0.0`` for every ``q`` (never divides by zero);
        * single observation / single bucket → that value for every ``q``
          (the bucket edge is clamped to the observed ``[vmin, vmax]``, so
          p50 == p99 == p99.9 == the value);
        * ``q`` outside ``[0, 1]`` raises ``ValueError``;
        * ``q == 0`` reads the lowest occupied bucket (rank 1).
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile q must be in [0, 1], got {q!r}")
        if not self.count:
            return 0.0
        target = max(1, math.ceil(q * self.count))
        cum = 0
        for idx in sorted(self.buckets,
                          key=lambda i: -math.inf if i is None else i):
            cum += self.buckets[idx]
            if cum >= target:
                edge = 0.0 if idx is None else self.GROWTH ** idx
                return min(max(edge, self.vmin), self.vmax)
        return self.vmax   # unreachable, kept for safety

    @property
    def p50(self) -> float:
        return self.quantile(0.50)

    @property
    def p99(self) -> float:
        return self.quantile(0.99)

    @property
    def p999(self) -> float:
        return self.quantile(0.999)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class MetricsRegistry:
    """Keyed store of Counter/Gauge/Histogram, one per (name, labels)."""

    def __init__(self):
        self._counters: dict = {}
        self._gauges: dict = {}
        self._histograms: dict = {}

    # -- instrument accessors (get-or-create) ------------------------------
    def counter(self, name: str, **labels) -> Counter:
        k = _key(name, labels)
        c = self._counters.get(k)
        if c is None:
            c = self._counters[k] = Counter(name, labels)
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        k = _key(name, labels)
        g = self._gauges.get(k)
        if g is None:
            g = self._gauges[k] = Gauge(name, labels)
        return g

    def histogram(self, name: str, **labels) -> Histogram:
        k = _key(name, labels)
        h = self._histograms.get(k)
        if h is None:
            h = self._histograms[k] = Histogram(name, labels)
        return h

    def histograms(self, prefix: str = "") -> dict:
        """Installed histograms whose name starts with ``prefix``, keyed by
        their full ``name{labels}`` key (sorted).  Read-only view used by the
        launch entrypoints to surface e.g. every ``noc.latency.*`` series."""
        return {k: h for k, h in sorted(self._histograms.items())
                if h.name.startswith(prefix)}

    @contextlib.contextmanager
    def timer(self, name: str, **labels):
        """Time a block into ``histogram(name)`` (seconds)."""
        h = self.histogram(name, **labels)
        t0 = time.perf_counter()
        try:
            yield h
        finally:
            h.observe(time.perf_counter() - t0)

    # -- engine publishers -------------------------------------------------
    def record_noc_stats(self, stats, **labels) -> None:
        """Publish a `NoCStats` under ``noc.*``.

        Flow counters accumulate (Counter.inc), the high-water-mark fields
        (`noc._MAX_MERGE_FIELDS`) merge by max (Gauge.set_max) — the same
        semantics as `NoCStats.add`, so repeated runs aggregate exactly
        like the engine's own accounting.
        """
        from ..core.noc import _MAX_MERGE_FIELDS
        for field, v in stats.as_dict().items():
            name = f"noc.{field}"
            if field in _MAX_MERGE_FIELDS:
                self.gauge(name, **labels).set_max(v)
            else:
                self.counter(name, **labels).inc(v)

    def record_moe_stats(self, st) -> None:
        """Publish a `MoEDispatchStats` under the canonical ``noc.moe.*``.

        Fields holding traced jax values (inside ``jit``) are skipped —
        publish host-side, e.g. from the train loop via
        :meth:`record_step_metrics`.
        """
        labels = {"engine": st.engine}
        if st.topology:
            labels["topology"] = st.topology
        for field, name in MOE_METRIC_NAMES.items():
            try:
                v = float(getattr(st, field))
            except Exception:
                continue
            if field == "peak_occupancy":
                self.gauge(name, **labels).set_max(v)
            else:
                self.counter(name, **labels).inc(v)
        self.gauge("noc.moe.capacity", **labels).set(st.capacity)
        self.gauge("noc.moe.capacity_factor", **labels).set(st.capacity_factor)

    def record_step_metrics(self, mets: dict) -> None:
        """Publish a train-step metric dict via :data:`STEP_METRIC_NAMES`."""
        for k, v in mets.items():
            name = STEP_METRIC_NAMES.get(k)
            if name is None:
                continue
            try:
                v = float(v)
            except Exception:
                continue
            if k == "moe_peak_occupancy":
                self.gauge(name).set_max(v)
            else:
                self.counter(name).inc(v)

    # -- exposition --------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-ready dict of every instrument."""
        return {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "histograms": {
                k: {"count": h.count, "sum": h.total,
                    "min": h.vmin or 0.0, "max": h.vmax or 0.0,
                    "mean": h.mean, "p50": h.p50, "p99": h.p99,
                    "p99.9": h.p999}
                for k, h in sorted(self._histograms.items())
            },
        }

    def prometheus(self) -> str:
        """Prometheus text exposition (histograms as summary quantiles)."""
        def pname(name: str) -> str:
            return name.replace(".", "_").replace("-", "_")

        def plabels(labels: dict, extra: Optional[dict] = None) -> str:
            items = dict(labels)
            if extra:
                items.update(extra)
            if not items:
                return ""
            inner = ",".join(f'{k}="{items[k]}"' for k in sorted(items))
            return f"{{{inner}}}"

        out = []
        for c in self._counters.values():
            out.append(f"# TYPE {pname(c.name)} counter")
            out.append(f"{pname(c.name)}{plabels(c.labels)} {c.value:g}")
        for g in self._gauges.values():
            out.append(f"# TYPE {pname(g.name)} gauge")
            out.append(f"{pname(g.name)}{plabels(g.labels)} {g.value:g}")
        for h in self._histograms.values():
            n = pname(h.name)
            out.append(f"# TYPE {n} summary")
            for q, v in (("0.5", h.p50), ("0.99", h.p99), ("0.999", h.p999)):
                out.append(f"{n}{plabels(h.labels, {'quantile': q})} {v:g}")
            out.append(f"{n}_sum{plabels(h.labels)} {h.total:g}")
            out.append(f"{n}_count{plabels(h.labels)} {h.count}")
        return "\n".join(out) + "\n"


# ---------------------------------------------------------------------------
# process-wide opt-in registry
# ---------------------------------------------------------------------------
_REGISTRY: Optional[MetricsRegistry] = None


def enable_metrics(registry: Optional[MetricsRegistry] = None) -> MetricsRegistry:
    """Install (or replace) the process-wide registry and return it."""
    global _REGISTRY
    _REGISTRY = registry if registry is not None else MetricsRegistry()
    return _REGISTRY


def disable_metrics() -> None:
    """Remove the process-wide registry (publishers become no-ops)."""
    global _REGISTRY
    _REGISTRY = None


def get_registry() -> Optional[MetricsRegistry]:
    """The installed registry, or ``None`` when metrics are off."""
    return _REGISTRY
