"""Opt-in observability for the NoC engines: tracing, export, metrics.

Three pieces, one contract:

* `tracer` — :class:`Tracer` (bounded ring buffer of structured events; see
  its module docstring for the full event schema) threaded through every
  engine via ``NoCExecutor(trace=...)`` / ``simulate_switch(tracer=...)`` /
  the app entry points' ``tracer=`` kwarg, and :func:`trace_stats`, which
  folds a complete trace back into the run's `NoCStats` **bit-exactly**.
* `export` — :func:`chrome_trace` (Perfetto/Chrome trace-event JSON, one
  track per router/link/bridge with counter tracks for queue depth and link
  load), :func:`validate_chrome_trace`, and the :func:`link_utilization` /
  :func:`heatmap` text/CSV reports (``launch/report.py --trace``).
* `metrics` — process-wide :class:`MetricsRegistry`
  (counter/gauge/log-bucketed histogram with p50/p99/p99.9, JSON snapshot +
  Prometheus text) that the engines, MoE dispatch and the train/serve loops
  all publish into under one ``noc.*`` naming scheme.

Everything is off by default and free when off: a disabled tracer is a
single ``is not None`` check in the engines (property-tested: zero events
allocated), a disabled registry a single ``get_registry() is None`` check.

``python -m repro.telemetry`` runs any case-study app traced and dumps the
Perfetto trace plus the link report.
"""
from .export import (chrome_trace, heatmap, link_utilization,
                     validate_chrome_trace, write_chrome_trace)
from .metrics import (MOE_METRIC_NAMES, STEP_METRIC_NAMES, Counter, Gauge,
                      Histogram, MetricsRegistry, disable_metrics,
                      enable_metrics, get_registry)
from .tracer import TraceEvent, Tracer, events_allocated, trace_stats

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MOE_METRIC_NAMES",
    "MetricsRegistry",
    "STEP_METRIC_NAMES",
    "TraceEvent",
    "Tracer",
    "chrome_trace",
    "disable_metrics",
    "enable_metrics",
    "events_allocated",
    "get_registry",
    "heatmap",
    "link_utilization",
    "trace_stats",
    "validate_chrome_trace",
    "write_chrome_trace",
]
