"""Opt-in observability for the NoC engines: tracing, export, metrics.

Four pieces, one contract (``docs/observability.md`` is the narrative):

* `tracer` — :class:`Tracer` (bounded ring buffer of structured events; see
  its module docstring for the full event schema) threaded through every
  engine via ``NoCExecutor(trace=...)`` / ``simulate_switch(tracer=...)`` /
  the app entry points' ``tracer=`` kwarg, and :func:`trace_stats`, which
  folds a complete trace back into the run's `NoCStats` **bit-exactly**.
* `profile` — :func:`profile_trace` rebuilds per-packet/per-message
  :class:`LatencyRecord`\\ s (inject→eject on the logical clock, decomposed
  exactly into serialization + hop + queueing + bridge), the run's critical
  path, and a gap attribution charging every cycle above the analytic
  bounds to a named resource; :func:`records_allocated` is the
  zero-overhead-off gate (the `events_allocated` analog).
* `export` — :func:`chrome_trace` (Perfetto/Chrome trace-event JSON, one
  track per router/link/bridge with counter tracks for queue depth and link
  load), :func:`validate_chrome_trace`, :func:`events_from_chrome` (the
  inverse — saved traces round-trip back into `trace_stats` /
  `profile_trace`), and the :func:`link_utilization` / :func:`heatmap`
  text/CSV reports (``launch/report.py --trace`` / ``--profile``).
* `metrics` — process-wide :class:`MetricsRegistry`
  (counter/gauge/log-bucketed histogram with p50/p99/p99.9, JSON snapshot +
  Prometheus text) that the engines, MoE dispatch, the train/serve loops
  and the profiler (``noc.latency.*``) all publish into under one
  ``noc.*`` naming scheme.
* `regress` — the perf-regression gate: re-runs the benchmark tables and
  diffs them against the committed ``benchmarks/BENCH_*.json`` baselines
  with noise-aware thresholds (``python -m repro.telemetry.regress``).

Everything is off by default and free when off: a disabled tracer is a
single ``is not None`` check in the engines (property-tested: zero events
allocated), a disabled registry a single ``get_registry() is None`` check,
and no `LatencyRecord` exists unless `profile_trace` is called.

``python -m repro.telemetry`` runs any case-study app traced and dumps the
Perfetto trace, the link report and (``--profile``) the bottleneck report.
"""
from .export import (chrome_trace, events_from_chrome, heatmap,
                     link_utilization, validate_chrome_trace,
                     write_chrome_trace)
from .metrics import (MOE_METRIC_NAMES, STEP_METRIC_NAMES, Counter, Gauge,
                      Histogram, MetricsRegistry, disable_metrics,
                      enable_metrics, get_registry)
from .profile import (CriticalPath, LatencyRecord, Profile, WaveProfile,
                      profile_trace, records_allocated)
from .tracer import TraceEvent, Tracer, events_allocated, trace_stats

__all__ = [
    "Counter",
    "CriticalPath",
    "Gauge",
    "Histogram",
    "LatencyRecord",
    "MOE_METRIC_NAMES",
    "MetricsRegistry",
    "Profile",
    "STEP_METRIC_NAMES",
    "TraceEvent",
    "Tracer",
    "WaveProfile",
    "chrome_trace",
    "disable_metrics",
    "enable_metrics",
    "events_allocated",
    "events_from_chrome",
    "get_registry",
    "heatmap",
    "link_utilization",
    "profile_trace",
    "records_allocated",
    "trace_stats",
    "validate_chrome_trace",
    "write_chrome_trace",
]
