"""Perf-regression gate: fresh benchmark runs vs the committed baselines.

The ``benchmarks/BENCH_*.json`` snapshots record the repo's perf trajectory
(deterministic engine counters + wall-clock timings with an environment
stamp).  This module turns them from a record into a **gate**: it re-runs
the snapshotted tables (``benchmarks/run.py --only <table>``), parses the
rows, and diffs them against the committed baselines with noise-aware
thresholds.  Nonzero exit ⇔ regression, with the offending metric and
delta named — wired into CI next to the functional gates.

Metric classes (the whole point — counters and timings fail differently):

* **counters** — deterministic engine numbers (cycles, stalls, flits,
  arb_losses, …).  Any mismatch is reported; a *worsening* fails the gate,
  an improvement or neutral drift is reported as such (the fix is to
  re-record the snapshot deliberately, via ``benchmarks/run.py --snapshot``,
  never to widen a tolerance).
* **timings** — ``us`` / any ``*_us`` key / throughput-like keys.  Noisy by
  nature: the fresh value is the **median of k runs** (default 3) and only
  a *relative worsening* beyond ``--timing-tol`` (default 25%) fails.
  **Off by default** (``--gate-timing off``): shared CI hosts show >50%
  wall-clock swings on an unchanged tree, so timing only gates on request
  — ``on`` always, ``auto`` when the baseline's recorded platform matches
  this host (for quiet dedicated machines).  The deterministic counters
  are the gate's teeth either way — an injected slowdown moves cycle
  counts, not just the clock (``table12_regress_selftest`` proves it).
* **text** — strings/bools (verdicts like ``deadlock_free=True``): any
  change fails.

Direction matters: ``speedup``/``accepted``/``*_per_s``-style metrics are
higher-is-better; everything else numeric lower-is-better.

Usage::

    python -m repro.telemetry.regress                  # gate all tables
    python -m repro.telemetry.regress --tables table9_congestion -k 5
    python -m repro.telemetry.regress --json report.json
    benchmarks/run.py --compare                        # same thing

The self-test that the gate actually trips lives in
``benchmarks/run.py::table12_profile`` (an injected ``buffer_depth=1``
slowdown must fail the diff) and runs in CI.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import statistics
import subprocess
import sys
from typing import Optional

# keys whose values are wall-clock / throughput noise, not deterministic
_TIMING_KEY = re.compile(
    r"(^|_)us$|per_s|fps|traced_over_untraced|speedup|gain")
# numeric metrics where bigger is better (everything else: smaller better)
_HIGHER_BETTER = re.compile(
    r"speedup|accepted|gain|per_s|fps|throughput|sat_rate")

DEFAULT_TABLES = ("table4_bmvm_iter", "table9_congestion", "table12_profile")


def _repo_root() -> str:
    # telemetry/ -> repro/ -> src/ -> repo root
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))


def metric_class(key: str, value) -> str:
    """``"timing"`` | ``"counter"`` | ``"text"`` for one row field."""
    if isinstance(value, str) or isinstance(value, bool):
        return "text"
    return "timing" if _TIMING_KEY.search(key) else "counter"


def _worse(key: str, base: float, new: float) -> bool:
    if _HIGHER_BETTER.search(key):
        return new < base
    return new > base


def _fmt(v) -> str:
    return f"{v:g}" if isinstance(v, (int, float)) else str(v)


def compare_rows(base_rows: list, new_rows: list, *,
                 timing_tol: float = 0.25,
                 gate_timing: bool = True) -> list:
    """Diff two row-dict lists (same format as ``BENCH_*.json["rows"]``).

    Returns a list of finding dicts ``{row, metric, cls, base, new, delta,
    verdict}`` where ``verdict`` is ``"regression"`` (fails the gate),
    ``"improvement"`` or ``"drift"`` (reported, non-fatal).  Rows are
    matched by name; rows present on only one side are a ``"regression"``
    (a vanished benchmark can hide a vanished feature).
    """
    base_by = {r["name"]: r for r in base_rows}
    new_by = {r["name"]: r for r in new_rows}
    findings = []
    for name in sorted(set(base_by) | set(new_by)):
        if name not in new_by:
            findings.append(dict(row=name, metric="(row)", cls="presence",
                                 base="present", new="missing", delta="",
                                 verdict="regression"))
            continue
        if name not in base_by:
            findings.append(dict(row=name, metric="(row)", cls="presence",
                                 base="missing", new="present", delta="",
                                 verdict="drift"))
            continue
        b, n = base_by[name], new_by[name]
        for key in sorted(set(b) & set(n) - {"name"}):
            bv, nv = b[key], n[key]
            cls = metric_class(key, bv)
            if cls == "text":
                if str(bv) != str(nv):
                    findings.append(dict(
                        row=name, metric=key, cls=cls, base=str(bv),
                        new=str(nv), delta="changed", verdict="regression"))
                continue
            if bv == nv:
                continue
            if cls == "timing":
                if not gate_timing:
                    continue
                rel = (nv - bv) / bv if bv else float("inf")
                if _HIGHER_BETTER.search(key):
                    rel = -rel
                if rel > timing_tol:
                    findings.append(dict(
                        row=name, metric=key, cls=cls, base=bv, new=nv,
                        delta=f"{rel:+.1%} (tol {timing_tol:.0%})",
                        verdict="regression"))
                continue
            # deterministic counter: any move is a finding
            verdict = ("regression" if _worse(key, bv, nv) else
                       "improvement")
            findings.append(dict(
                row=name, metric=key, cls=cls, base=bv, new=nv,
                delta=f"{nv - bv:+g}", verdict=verdict))
    return findings


def run_fresh(table: str, *, fast: bool = True, k: int = 3,
              repo_root: Optional[str] = None) -> list:
    """Run ``benchmarks/run.py --only <table>`` ``k`` times and fold the
    parsed rows: deterministic fields from the first run (they must not
    move between invocations — if they do, that IS the finding), timing
    fields replaced by the median across runs (noise suppression)."""
    root = repo_root or _repo_root()
    sys.path.insert(0, os.path.join(root, "benchmarks"))
    try:
        from run import _parse_row   # noqa: the benchmark's own parser
    finally:
        sys.path.pop(0)
    cmd = [sys.executable, "-m", "benchmarks.run", "--only", table]
    if fast:
        cmd.append("--fast")
    env = dict(os.environ)
    src = os.path.join(root, "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    samples = []
    for _ in range(max(1, k)):
        out = subprocess.run(cmd, capture_output=True, text=True, cwd=root,
                             env=env)
        if out.returncode != 0:
            raise RuntimeError(
                f"benchmarks.run --only {table} failed "
                f"(exit {out.returncode}):\n{out.stdout[-2000:]}"
                f"\n{out.stderr[-2000:]}")
        rows = [_parse_row(ln) for ln in out.stdout.splitlines()
                if ln.startswith(table.split("_")[0]) and "," in ln
                and not ln.startswith("#")]
        if not rows:
            raise RuntimeError(
                f"benchmarks.run --only {table}: no rows parsed from:\n"
                f"{out.stdout[-2000:]}")
        samples.append(rows)
    folded = []
    for i, row in enumerate(samples[0]):
        merged = dict(row)
        for key, v in row.items():
            if key != "name" and metric_class(key, v) == "timing":
                vals = [s[i][key] for s in samples
                        if i < len(s) and key in s[i]]
                merged[key] = statistics.median(vals)
        folded.append(merged)
    return folded


def _load_baseline(table: str, baseline_dir: str) -> Optional[dict]:
    sys.path.insert(0, os.path.join(_repo_root(), "benchmarks"))
    try:
        from run import SNAPSHOTS
    finally:
        sys.path.pop(0)
    fname = SNAPSHOTS.get(table)
    if fname is None:
        return None
    path = os.path.join(baseline_dir, fname)
    if not os.path.exists(path):
        return None
    with open(path) as fh:
        return json.load(fh)


def _platform_matches(meta: dict) -> bool:
    import platform

    return (meta.get("platform") == platform.platform()
            and meta.get("python") == platform.python_version())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.regress",
        description="perf-regression gate vs committed BENCH_*.json")
    ap.add_argument("--tables", default=",".join(DEFAULT_TABLES),
                    help="comma-separated snapshot tables to gate")
    ap.add_argument("--full", action="store_true",
                    help="run the full (non --fast) benchmark variants; "
                         "only valid against full-recorded baselines")
    ap.add_argument("-k", type=int, default=3,
                    help="fresh runs per table; timings take the median")
    ap.add_argument("--timing-tol", type=float, default=0.25,
                    help="relative worsening tolerated on timing metrics")
    ap.add_argument("--gate-timing", choices=("auto", "on", "off"),
                    default="off",
                    help="gate wall-clock metrics: off (default — counters "
                         "always gate), on, or auto = only when the "
                         "baseline was recorded on this platform")
    ap.add_argument("--baseline-dir", default=None,
                    help="directory holding BENCH_*.json (default: the "
                         "repo's benchmarks/)")
    ap.add_argument("--fresh-json", default=None,
                    help="read fresh rows from this JSON instead of "
                         "re-running (as written by --save-fresh)")
    ap.add_argument("--save-fresh", default=None,
                    help="write the fresh rows to this JSON for reuse")
    ap.add_argument("--json", default=None,
                    help="write the findings report as JSON here")
    args = ap.parse_args(argv)

    root = _repo_root()
    baseline_dir = args.baseline_dir or os.path.join(root, "benchmarks")
    fast = not args.full
    tables = [t for t in args.tables.split(",") if t]
    prior_fresh = {}
    if args.fresh_json:
        with open(args.fresh_json) as fh:
            prior_fresh = json.load(fh)

    all_findings, fresh_out = [], {}
    failed = False
    for table in tables:
        base = _load_baseline(table, baseline_dir)
        if base is None:
            print(f"[regress] {table}: no committed baseline — skipping "
                  f"(record one with benchmarks/run.py --snapshot)")
            continue
        if bool(base.get("fast")) != fast:
            print(f"[regress] {table}: baseline recorded with "
                  f"fast={base.get('fast')} but this run is fast={fast}; "
                  f"refusing an apples-to-oranges diff", file=sys.stderr)
            failed = True
            continue
        gate_timing = (args.gate_timing == "on"
                       or (args.gate_timing == "auto"
                           and _platform_matches(base.get("meta", {}))))
        if table in prior_fresh:
            fresh = prior_fresh[table]
        else:
            fresh = run_fresh(table, fast=fast, k=args.k, repo_root=root)
        fresh_out[table] = fresh
        findings = compare_rows(base["rows"], fresh,
                                timing_tol=args.timing_tol,
                                gate_timing=gate_timing)
        regressions = [f for f in findings if f["verdict"] == "regression"]
        tag = "FAIL" if regressions else "ok"
        print(f"[regress] {table}: {len(base['rows'])} rows, "
              f"{len(findings)} finding(s), "
              f"{len(regressions)} regression(s) "
              f"[timing gate {'on' if gate_timing else 'off'}] -> {tag}")
        for f in findings:
            f["table"] = table
            mark = {"regression": "!!", "improvement": "++"}.get(
                f["verdict"], "~ ")
            print(f"  {mark} {f['row']}.{f['metric']} [{f['cls']}]: "
                  f"{_fmt(f['base'])} -> {_fmt(f['new'])}  {f['delta']}  "
                  f"({f['verdict']})")
        all_findings.extend(findings)
        failed = failed or bool(regressions)

    if args.save_fresh:
        with open(args.save_fresh, "w") as fh:
            json.dump(fresh_out, fh, indent=1, sort_keys=True)
            fh.write("\n")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"failed": failed, "findings": all_findings}, fh,
                      indent=1, sort_keys=True)
            fh.write("\n")
    if failed:
        print("[regress] FAIL: performance regressed vs committed "
              "baselines (see metrics above)", file=sys.stderr)
        return 1
    print("[regress] all gated tables within thresholds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
