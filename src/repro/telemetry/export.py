"""Trace exporters: Chrome trace-event / Perfetto JSON + link heatmaps.

`chrome_trace` lowers a :class:`~repro.telemetry.tracer.Tracer` into the
Chrome trace-event JSON object format (loadable in ``ui.perfetto.dev`` or
``chrome://tracing``): one thread-track per engine track (routers, links,
bridges, the wave/engine timelines), complete-event spans (``ph=X``) for
waves/scatter/route/gather, instants (``ph=i``) for per-cycle and
per-message events, and counter tracks (``ph=C``) for queue depth, link
load and bridge FIFO occupancy.  Logical NoC ticks map 1:1 onto trace
microseconds.

`validate_chrome_trace` is a hand-rolled structural checker for the subset
of the format we emit (no external jsonschema dependency); CI validates
both freshly-exported traces and the committed sample against it.

`link_utilization` + `heatmap` rebuild the per-link byte totals from the
``link`` counter events — accepting either a live tracer or an exported
JSON document — and render them as an n×n text matrix or CSV
(``launch/report.py --trace`` wires this into the report CLI).
"""
from __future__ import annotations

import json
import re
from typing import Union

from .tracer import TraceEvent, Tracer

_PID = 0
_LINK_TRACK = re.compile(r"^(?:link|bridge) (\d+)->(\d+)$")


def chrome_trace(trace: Union[Tracer, list], *, process_name: str = "repro.noc") -> dict:
    """Lower a trace to a Chrome trace-event JSON document (dict)."""
    events = trace.events() if isinstance(trace, Tracer) else list(trace)
    tids: dict = {}
    out = [{"name": "process_name", "ph": "M", "pid": _PID, "tid": 0,
            "args": {"name": process_name}}]

    def tid_of(track: str) -> int:
        t = tids.get(track)
        if t is None:
            t = tids[track] = len(tids) + 1
            out.append({"name": "thread_name", "ph": "M", "pid": _PID,
                        "tid": t, "args": {"name": track}})
        return t

    for ev in events:
        base = {"name": ev.name, "pid": _PID, "tid": tid_of(ev.track),
                "ts": ev.ts}
        if ev.kind == "span":
            base["ph"] = "X"
            base["dur"] = max(ev.dur, 1)
            base["args"] = ev.args or {}
        elif ev.kind == "counter":
            base["ph"] = "C"
            base["args"] = {"value": ev.value}
        else:
            base["ph"] = "i"
            base["s"] = "t"
            base["args"] = ev.args or {}
        out.append(base)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(path, trace: Union[Tracer, list, dict]) -> None:
    """Serialize a tracer (or a prebuilt document) to ``path``."""
    doc = trace if isinstance(trace, dict) else chrome_trace(trace)
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1)
        fh.write("\n")


def validate_chrome_trace(doc: dict) -> int:
    """Structural check of a Chrome trace-event document.

    Verifies the envelope, per-event required fields by phase, numeric
    timestamps/durations, counter args, and that every (pid, tid) carrying
    events has ``thread_name`` metadata.  Raises ``ValueError`` naming the
    first offending event; returns the number of events checked.
    """
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("not a trace document: missing 'traceEvents'")
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("'traceEvents' must be a list")
    named_threads = set()
    used_threads = set()
    for i, ev in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            raise ValueError(f"{where}: not an object")
        ph = ev.get("ph")
        if ph not in ("X", "i", "C", "M"):
            raise ValueError(f"{where}: unsupported ph {ph!r}")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            raise ValueError(f"{where}: missing event name")
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                raise ValueError(f"{where}: {k} must be an int")
        if ph == "M":
            if ev["name"] not in ("process_name", "thread_name"):
                raise ValueError(f"{where}: unknown metadata {ev['name']!r}")
            if not isinstance(ev.get("args", {}).get("name"), str):
                raise ValueError(f"{where}: metadata needs args.name")
            if ev["name"] == "thread_name":
                named_threads.add((ev["pid"], ev["tid"]))
            continue
        used_threads.add((ev["pid"], ev["tid"]))
        if not isinstance(ev.get("ts"), (int, float)):
            raise ValueError(f"{where}: ts must be a number")
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"{where}: span needs dur >= 0")
        if ph == "C":
            args = ev.get("args")
            if (not isinstance(args, dict) or not args
                    or not all(isinstance(v, (int, float))
                               for v in args.values())):
                raise ValueError(f"{where}: counter needs numeric args")
        if ph == "i" and ev.get("s", "t") not in ("g", "p", "t"):
            raise ValueError(f"{where}: bad instant scope {ev.get('s')!r}")
    orphans = used_threads - named_threads
    if orphans:
        raise ValueError(f"threads without thread_name metadata: "
                         f"{sorted(orphans)}")
    return len(evs)


def events_from_chrome(doc: dict) -> list:
    """Inverse of :func:`chrome_trace`: rebuild `TraceEvent`s from a saved
    Chrome trace document.

    Track names are recovered from the ``thread_name`` metadata, spans
    (``ph=X``) back to kind ``"span"`` with their duration, counters
    (``ph=C``) to kind ``"counter"`` with ``args.value``, instants to kind
    ``"instant"`` with their args; metadata events are dropped.  The result
    feeds `repro.telemetry.profile.profile_trace` (and `trace_stats`), so a
    trace written to disk round-trips into the same profile the live tracer
    would give — ``launch/report.py --profile`` is exactly this path.
    """
    names = {(ev["pid"], ev["tid"]): ev["args"]["name"]
             for ev in doc.get("traceEvents", ())
             if ev.get("ph") == "M" and ev.get("name") == "thread_name"}
    out = []
    for ev in doc.get("traceEvents", ()):
        ph = ev.get("ph")
        if ph == "M":
            continue
        track = names.get((ev.get("pid"), ev.get("tid")), "")
        if ph == "X":
            out.append(TraceEvent(int(ev["ts"]), ev["name"], track, "span",
                                  dur=int(ev["dur"]),
                                  args=ev.get("args") or None))
        elif ph == "C":
            out.append(TraceEvent(int(ev["ts"]), ev["name"], track,
                                  "counter", value=ev["args"]["value"]))
        else:
            out.append(TraceEvent(int(ev["ts"]), ev["name"], track,
                                  "instant", args=ev.get("args") or None))
    return out


# ---------------------------------------------------------------------------
# link-utilization heatmap
# ---------------------------------------------------------------------------

def link_utilization(trace: Union[Tracer, list, dict]) -> dict:
    """Per-link byte totals ``{(src, dst): bytes}``.

    Accepts a live tracer / event list (sums ``link`` counter events) or an
    exported Chrome trace document (recovers the link from the track's
    ``thread_name`` metadata).  Bridge wire traffic is included under its
    own ``(src, dst)`` pairs via the ``bridge_tx`` events, so a partitioned
    run's serial links show up next to the router links they bridge; the
    buffered switch emits per-link flit-byte counters at the end of each
    run, so ``mode="buffered"`` heatmaps are populated too.
    """
    util: dict = {}

    def add(track: str, nbytes: float) -> None:
        m = _LINK_TRACK.match(track)
        if m:
            key = (int(m.group(1)), int(m.group(2)))
            util[key] = util.get(key, 0) + int(nbytes)

    if isinstance(trace, dict):
        names = {(ev["pid"], ev["tid"]): ev["args"]["name"]
                 for ev in trace.get("traceEvents", ())
                 if ev.get("ph") == "M" and ev.get("name") == "thread_name"}
        for ev in trace.get("traceEvents", ()):
            track = names.get((ev.get("pid"), ev.get("tid")), "")
            if ev.get("ph") == "C" and ev.get("name") == "link":
                add(track, ev["args"]["value"])
            elif ev.get("ph") == "i" and ev.get("name") == "bridge_tx":
                add(track, ev["args"]["wire_bytes"])
    else:
        events = trace.events() if isinstance(trace, Tracer) else trace
        for ev in events:
            assert isinstance(ev, TraceEvent)
            if ev.kind == "counter" and ev.name == "link":
                add(ev.track, ev.value)
            elif ev.name == "bridge_tx":
                add(ev.track, ev.args["wire_bytes"])
    return util


def heatmap(util: dict, *, csv: bool = False) -> str:
    """Render `link_utilization` output as text matrix or CSV."""
    if csv:
        lines = ["src,dst,bytes"]
        for (s, d), b in sorted(util.items()):
            lines.append(f"{s},{d},{b}")
        return "\n".join(lines)
    if not util:
        return "no link traffic recorded"
    nodes = sorted({s for s, _ in util} | {d for _, d in util})
    width = max(7, max(len(str(b)) for b in util.values()) + 1)
    head = "src\\dst" + "".join(f"{d:>{width}}" for d in nodes)
    lines = [head]
    for s in nodes:
        row = f"{s:>7}"
        for d in nodes:
            b = util.get((s, d), 0)
            row += f"{b if b else '.':>{width}}"
        lines.append(row)
    lines.append(f"total bytes: {sum(util.values())} over {len(util)} links")
    return "\n".join(lines)
