"""Synthetic traffic patterns for the buffered wormhole switch.

Standard NoC evaluation workloads (Dally & Towles ch. 25 vocabulary), used by
the ``table9_congestion`` benchmark and the property suite:

* ``uniform``   — each packet picks a destination uniformly among the other
                  nodes (the classic baseline; stresses bisection links);
* ``hotspot``   — a fraction ``hotspot_frac`` of packets target one node,
                  the rest uniform (stresses one ejection port / subtree —
                  the MoE "popular expert" regime);
* ``transpose`` — fixed permutation partner per node (matrix-transpose
                  ``(x, y) -> (y, x)`` on square 2D fabrics, bit-reversal
                  analog ``n-1-i`` elsewhere; adversarial for X-Y
                  dimension-ordered routing);
* ``bursty``    — destinations uniform but injection clumps into back-to-back
                  bursts of ``burst_len`` packets with exponential (Poisson
                  process) gaps between bursts, same long-run offered rate.

Injection times model a Poisson-ish open-loop source: per node, inter-packet
gaps are exponential with mean ``packet_flits / injection_rate`` cycles, so
the offered load is ``injection_rate`` flits/cycle/node — directly comparable
to :func:`repro.core.switch.saturation_rate`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .switch import Packet
from .topology import Mesh2D, Topology

PATTERNS = ("uniform", "hotspot", "transpose", "bursty")


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    pattern: str = "uniform"
    injection_rate: float = 0.1   # offered load, flits/cycle/node
    packet_flits: int = 4
    n_packets: int = 64           # packets per source node
    hotspot: int = 0              # hotspot destination node
    hotspot_frac: float = 0.5     # fraction of traffic aimed at the hotspot
    burst_len: int = 4            # packets per burst (bursty pattern)
    seed: int = 0

    def __post_init__(self):
        if self.pattern not in PATTERNS:
            raise ValueError(f"unknown pattern {self.pattern!r}; "
                             f"expected one of {PATTERNS}")
        if not 0.0 < self.injection_rate:
            raise ValueError("injection_rate must be positive")
        if not 0.0 <= self.hotspot_frac <= 1.0:
            raise ValueError(f"hotspot_frac={self.hotspot_frac} must be "
                             f"in [0, 1]")
        if self.packet_flits < 1:
            raise ValueError("packet_flits must be >= 1")
        if self.burst_len < 1:
            raise ValueError("burst_len must be >= 1")
        if self.n_packets < 0:
            raise ValueError("n_packets must be >= 0")


def transpose_partner(topo: Topology, node: int) -> int:
    """Fixed permutation partner: ``(x, y) -> (y, x)`` on square 2D fabrics,
    index reversal otherwise; self-partners redirect to the next node so the
    pattern always exercises the network."""
    if isinstance(topo, Mesh2D) and topo.rx == topo.ry:
        x, y = topo.coords(node)
        p = topo.node(y, x)
    else:
        p = topo.n_nodes - 1 - node
    if p == node:
        p = (node + 1) % topo.n_nodes
    return p


def traffic_matrix(topo: Topology, cfg: TrafficConfig) -> np.ndarray:
    """Destination distribution ``matrix[s, d]`` (rows sum to 1) for
    ``cfg.pattern`` — the input :func:`repro.core.switch.saturation_rate`
    expects.  ``bursty`` shares uniform's spatial distribution; only its
    injection-time process differs."""
    n = topo.n_nodes
    if n < 2:       # no destination exists; there is no traffic to describe
        return np.zeros((n, n))
    uni = np.full((n, n), 1.0 / (n - 1))
    np.fill_diagonal(uni, 0.0)
    if cfg.pattern in ("uniform", "bursty"):
        return uni
    if cfg.pattern == "hotspot":
        m = (1.0 - cfg.hotspot_frac) * uni
        hot = np.full(n, cfg.hotspot_frac)
        hot[cfg.hotspot] = 0.0
        m[:, cfg.hotspot] += hot
        # renormalize rows (the hotspot's own row lost its hotspot share);
        # at hotspot_frac=1.0 that row is all-zero — it sends uniformly
        # rather than dividing by zero
        sums = m.sum(axis=1, keepdims=True)
        m = np.where(sums > 0.0, m / np.where(sums > 0.0, sums, 1.0), uni)
        return m
    if cfg.pattern == "transpose":
        m = np.zeros((n, n))
        for s in range(n):
            m[s, transpose_partner(topo, s)] = 1.0
        return m
    raise AssertionError(cfg.pattern)


def generate_traffic(topo: Topology, cfg: TrafficConfig) -> list[Packet]:
    """Draw a concrete packet workload: ``cfg.n_packets`` packets per source
    with pattern-distributed destinations and rate-controlled injection
    times.  Deterministic in ``cfg.seed``."""
    n = topo.n_nodes
    if n < 2:       # single-node fabric: nothing can be sent anywhere
        return []
    rng = np.random.default_rng(cfg.seed)
    gap_mean = cfg.packet_flits / cfg.injection_rate
    packets: list[Packet] = []
    for s in range(n):
        if cfg.pattern == "bursty":
            # bursts of burst_len back-to-back packets, exponential gaps
            # between bursts scaled to keep the long-run rate
            t = 0.0
            k = 0
            while k < cfg.n_packets:
                for _ in range(min(cfg.burst_len, cfg.n_packets - k)):
                    packets.append(self_pkt(topo, cfg, rng, s, int(t)))
                    k += 1
                t += rng.exponential(cfg.burst_len * gap_mean)
        else:
            t = 0.0
            for _ in range(cfg.n_packets):
                packets.append(self_pkt(topo, cfg, rng, s, int(t)))
                t += rng.exponential(gap_mean)
    return packets


def self_pkt(topo: Topology, cfg: TrafficConfig, rng: np.random.Generator,
             src: int, t: int) -> Packet:
    """Draw one packet from ``src`` at time ``t`` per the pattern."""
    n = topo.n_nodes
    if cfg.pattern == "transpose":
        dst = transpose_partner(topo, src)
    elif (cfg.pattern == "hotspot" and src != cfg.hotspot
          and rng.random() < cfg.hotspot_frac):
        dst = cfg.hotspot
    else:
        dst = int(rng.integers(n - 1))
        if dst >= src:
            dst += 1
    return Packet(src, dst, cfg.packet_flits, t_inject=t)
