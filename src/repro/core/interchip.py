"""Inter-chip bridge subsystem: compiled route programs across pod cuts.

The paper's last automated step (§III, Fig. 6) partitions the "on-chip" NoC
links so the same application runs seamlessly across chips/FPGAs, with each
cut link realized over a narrow quasi-serial connection.  This module is that
step for the compiled flit programs: it takes any `routing.RouteProgram` plus
a `partition.PartitionPlan` and splits it into **per-pod programs joined by
explicit bridge nodes** — one `BridgeLink` per directed physical topology
link the schedule drives across the cut.  Every pod-crossing hop funnels its
rotating-buffer traffic through a `QuasiSerdesConfig`-framed serial link that
time-multiplexes the wide on-chip flits onto ``lanes`` narrow beats, with a
FIFO-depth and bandwidth model per bridge.

Three interpreters share the compiled `BridgedProgram`, mirroring the engine
contract of `core.routing`:

* :func:`simulate_bridged_program` — numpy round-by-round execution that
  physically serializes every crossing buffer into wire words and back
  (lossless framing, so delivery is bit-identical to the unpartitioned
  `routing.simulate_route_program`) and *defines* :class:`BridgeStats`:
  per-bridge beats, serialized wire bytes, stall rounds (back-pressure +
  drain), and peak FIFO occupancy;
* :func:`bridge_program_stats` — analytic stats from the static traversal
  schedule, exactly matching the simulator (the spmd executor uses this so
  partitioned NoCStats never need a numpy re-run);
* :func:`run_bridged_program` — the shard_map lowering: the program runs
  *linearized* over the device mesh built by `partition.mesh_for_partition`
  (a 2D ``(pod, node)`` mesh when the plan's pods are equal contiguous
  blocks), intra-pod hops stay single `lax.ppermute` rounds while cut hops
  go through `serdes.send_over_link` — encode, ``lanes`` serialized beat
  ppermutes, decode — the same machinery `launch.steps.grads_serdes` uses
  for the cross-pod gradient exchange.

Bridge cost model
-----------------
A bridge serializes each crossing buffer into ``ceil(bytes / beat_bytes)``
wire words, padded to a multiple of ``lanes`` (the serdes framing rule of
`serdes.plan`).  Words enqueue into the bridge FIFO in the NoC round they
arrive; the bridge drains one word per lane per round (``lanes`` words/round).
Occupancy beyond ``fifo_depth`` back-pressures the pod-synchronous schedule —
those are stall rounds, as is the final drain after the last program round.
``beats`` counts serial-lane clock cycles spent transmitting
(``words / lanes`` per crossing, exact after padding).  The data path is
always lossless (compression is a *planning* knob for the cut objective —
see `partition.placement_cost` / `partition.optimize_pod_cut` — never a
transform of in-flight flit bytes).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from . import serdes as qserdes
from .partition import PartitionPlan
from .routing import RouteProgram, ScheduleStats, run_route_program


@dataclasses.dataclass(frozen=True)
class BridgeConfig:
    """Per-bridge serial-link model: serdes framing + FIFO depth (in wire
    words).  ``serdes.compress`` only shapes planning costs; the bridge data
    path always moves the exact flit bytes."""

    serdes: qserdes.QuasiSerdesConfig = dataclasses.field(
        default_factory=qserdes.QuasiSerdesConfig)
    fifo_depth: int = 64

    def __post_init__(self):
        assert self.fifo_depth >= 1


@dataclasses.dataclass(frozen=True)
class BridgeLink:
    """One directed physical topology link cut by the partition — the
    'explicit bridge node' pair stitched between the per-pod programs."""

    src: int
    dst: int
    src_pod: int
    dst_pod: int


@dataclasses.dataclass(frozen=True)
class BridgedRound:
    """One NoC round of the partitioned schedule: physical link traversals
    split at the cut.  Every traversal moves ``cube_nbytes // den`` bytes."""

    den: int
    intra: tuple[tuple[int, int], ...]     # on-chip (src, dst) node pairs
    cross: tuple[int, ...]                 # bridge indices carrying traffic


@dataclasses.dataclass(frozen=True)
class PodProgram:
    """The per-pod view of the split schedule: the hops that stay on this
    chip plus the bridges stitched to its boundary."""

    pod: int
    nodes: tuple[int, ...]
    rounds: tuple[tuple[tuple[int, int], ...], ...]   # intra hops per round
    egress: tuple[int, ...]                # bridge indices leaving this pod
    ingress: tuple[int, ...]               # bridge indices entering this pod


@dataclasses.dataclass(frozen=True)
class BridgedProgram:
    """A RouteProgram split across a pod cut: per-pod programs + bridges."""

    prog: RouteProgram
    pod_of_node: tuple[int, ...]
    bridges: tuple[BridgeLink, ...]
    rounds: tuple[BridgedRound, ...]
    pods: tuple[PodProgram, ...]
    cfg: BridgeConfig
    wire_cfg: qserdes.QuasiSerdesConfig    # cfg.serdes with compression off

    @property
    def n_pods(self) -> int:
        return len(self.pods)


@dataclasses.dataclass
class BridgeStats:
    """Serial-link accounting of one partitioned execution (value-independent;
    defined by the round-by-round simulator, matched exactly by
    :func:`bridge_program_stats`)."""

    n_bridges: int = 0
    beats: int = 0            # serial-lane clock cycles spent transmitting
    wire_bytes: int = 0       # serialized bytes incl. word/lane padding
    stall_rounds: int = 0     # back-pressure + final-drain rounds
    peak_fifo: int = 0        # max FIFO occupancy over bridges, in wire words
    per_bridge: dict = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# compile: split a RouteProgram at the cut
# ---------------------------------------------------------------------------

def _walk_rounds(prog: RouteProgram) -> Iterator[tuple[int, list[tuple[int, int]]]]:
    """Yield ``(den, physical (src, dst) link traversals)`` per NoC round, in
    execution order.  Axis-local hop pairs are expanded to global node ids
    (every row/column of a 2D phase concurrently); each traversal moves
    ``cube_nbytes // den`` bytes of the wave's message cube."""
    n = prog.n_nodes
    if prog.fused:
        yield n * n, [(s, d) for s in range(n) for d in range(n) if s != d]
        return
    if len(prog.phases) == 1:
        for rnd in prog.phases[0].rounds:
            yield n, [p for mv in rnd.moves for p in mv.perm]
        return
    (_, ry), (_, rx) = prog.axes
    phase_x, phase_y = prog.phases
    for rnd in phase_x.rounds:
        yield n, [(y * rx + s, y * rx + d)
                  for mv in rnd.moves for s, d in mv.perm for y in range(ry)]
    for rnd in phase_y.rounds:
        yield n, [(s * rx + x, d * rx + x)
                  for mv in rnd.moves for s, d in mv.perm for x in range(rx)]


def compile_bridges(prog: RouteProgram, plan: PartitionPlan,
                    cfg: Optional[BridgeConfig] = None) -> BridgedProgram:
    """Split a compiled route program at a partition plan's pod cut."""
    pod_of = tuple(plan.pod_of_node)
    if len(pod_of) != prog.n_nodes:
        raise ValueError(f"plan covers {len(pod_of)} nodes, "
                         f"program has {prog.n_nodes}")
    cfg = cfg or BridgeConfig(serdes=plan.serdes_cfg)
    wire_cfg = dataclasses.replace(cfg.serdes, compress="none")
    bridges: list[BridgeLink] = []
    bridge_of: dict[tuple[int, int], int] = {}
    rounds: list[BridgedRound] = []
    for den, pairs in _walk_rounds(prog):
        intra, cross = [], []
        for s, d in pairs:
            if pod_of[s] == pod_of[d]:
                intra.append((s, d))
            else:
                if (s, d) not in bridge_of:
                    bridge_of[(s, d)] = len(bridges)
                    bridges.append(BridgeLink(s, d, pod_of[s], pod_of[d]))
                cross.append(bridge_of[(s, d)])
        rounds.append(BridgedRound(den, tuple(intra), tuple(cross)))
    n_pods = max(pod_of) + 1 if pod_of else 1
    pods = tuple(
        PodProgram(
            p,
            tuple(i for i in range(prog.n_nodes) if pod_of[i] == p),
            tuple(tuple(pr for pr in r.intra if pod_of[pr[0]] == p)
                  for r in rounds),
            tuple(i for i, b in enumerate(bridges) if b.src_pod == p),
            tuple(i for i, b in enumerate(bridges) if b.dst_pod == p),
        )
        for p in range(n_pods))
    return BridgedProgram(prog, pod_of, tuple(bridges), tuple(rounds), pods,
                          cfg, wire_cfg)


# ---------------------------------------------------------------------------
# bridge FIFO / bandwidth model (shared by simulator and analytic stats)
# ---------------------------------------------------------------------------

class _BridgeSim:
    """FIFO + serialization model of every bridge, advanced round by round.
    Both the numpy simulator and the analytic stats drive this same machine
    from the same arrival schedule — which is what makes them exact.

    Per bridge and round: crossing frames land in the upstream router output
    (``pending``); the FIFO admits from it up to ``fifo_depth`` and transmits
    one word per lane.  While any upstream words remain un-admitted after the
    scheduled round, the synchronous schedule *stalls* (back-pressure — the
    slowest bridge gates every pod), repeating admit+transmit rounds; the
    final FIFO drain after the last program round stalls the same way.  Total
    stall rounds are bandwidth-limited (≈ words/lanes beyond what the
    schedule hides) and therefore depth-invariant; the FIFO depth bounds
    ``peak_fifo`` and decides *where* the stalls land (spread through the
    schedule vs. one terminal drain)."""

    def __init__(self, bprog: BridgedProgram, tracer=None):
        self.cfg = bprog.cfg
        self.keys = [(b.src, b.dst) for b in bprog.bridges]
        self.links = [dict(occ=0, pending=0, peak=0, words=0, beats=0, stalls=0)
                      for _ in bprog.bridges]
        self.stall_rounds = 0
        # telemetry: one machine == one trace source, shared by the simulator
        # and the analytic stats — which is why their event streams agree
        self.tracer = tracer
        self._t0 = tracer.clock if tracer is not None else 0
        self._round = 0
        if tracer is not None and self.links:
            tracer.instant("bridge_cfg", "bridges", ts=self._t0,
                           n=len(self.links), **self.cfg.serdes.trace_args())

    def words_for(self, nbytes: int) -> int:
        """Wire words one crossing of ``nbytes`` occupies: ceil to whole
        words, padded so the frame splits evenly into lanes (serdes rule)."""
        s = self.cfg.serdes
        n_words = -(-nbytes // s.beat_bytes)
        return -(-n_words // s.lanes) * s.lanes

    def push(self, bridge_idx: int, nbytes: int) -> None:
        s = self.cfg.serdes
        w = self.words_for(nbytes)
        lk = self.links[bridge_idx]
        lk["pending"] += w
        lk["words"] += w
        lk["beats"] += w // s.lanes
        if self.tracer is not None:
            bs, bd = self.keys[bridge_idx]
            self.tracer.instant("bridge_tx", f"bridge {bs}->{bd}",
                                ts=self._t0 + self._round, words=w,
                                beats=w // s.lanes,
                                wire_bytes=w * s.beat_bytes)

    def _admit_transmit(self, idx: int, lk: dict) -> None:
        take = min(lk["pending"], self.cfg.fifo_depth - lk["occ"])
        lk["occ"] += take
        lk["pending"] -= take
        lk["peak"] = max(lk["peak"], lk["occ"])
        if self.tracer is not None:
            # post-admit / pre-transmit: exactly the peak-update point, so
            # the counter track's max IS bridge_peak_fifo
            bs, bd = self.keys[idx]
            self.tracer.counter("bridge_fifo", f"bridge {bs}->{bd}",
                                lk["occ"], ts=self._t0 + self._round)
        lk["occ"] = max(0, lk["occ"] - self.cfg.serdes.lanes)

    def end_round(self) -> None:
        round_stall, gating = 0, -1
        for idx, lk in enumerate(self.links):
            self._admit_transmit(idx, lk)
            s = 0
            while lk["pending"]:
                self._admit_transmit(idx, lk)
                s += 1
            lk["stalls"] += s
            if s > round_stall:
                round_stall, gating = s, idx
        self.stall_rounds += round_stall
        if self.tracer is not None and round_stall:
            # the slowest bridge gates the synchronous schedule: naming it in
            # the event is what lets the profiler charge the stall to a
            # concrete resource instead of "the bridges"
            bs, bd = self.keys[gating]
            self.tracer.instant("bridge_stall", "bridges",
                                ts=self._t0 + self._round, rounds=round_stall,
                                src=bs, dst=bd)
        self._round += 1

    def finish(self) -> BridgeStats:
        lanes = self.cfg.serdes.lanes
        beat_b = self.cfg.serdes.beat_bytes
        drain, gating = 0, -1
        for idx, lk in enumerate(self.links):
            s = -(-lk["occ"] // lanes)
            lk["stalls"] += s
            while self.tracer is not None and lk["occ"] > 0:
                self._admit_transmit(idx, lk)   # traced terminal drain
            lk["occ"] = 0
            if s > drain:
                drain, gating = s, idx
        self.stall_rounds += drain
        if self.tracer is not None and drain:
            bs, bd = self.keys[gating]
            self.tracer.instant("bridge_stall", "bridges",
                                ts=self._t0 + self._round, rounds=drain,
                                src=bs, dst=bd)
        per = {k: dict(beats=lk["beats"], wire_bytes=lk["words"] * beat_b,
                       stall_rounds=lk["stalls"], peak_fifo=lk["peak"])
               for k, lk in zip(self.keys, self.links)}
        return BridgeStats(
            n_bridges=len(self.links),
            beats=sum(lk["beats"] for lk in self.links),
            wire_bytes=sum(lk["words"] for lk in self.links) * beat_b,
            stall_rounds=self.stall_rounds,
            peak_fifo=max((lk["peak"] for lk in self.links), default=0),
            per_bridge=per)


def bridge_program_stats(bprog: BridgedProgram, cube_nbytes: int,
                         tracer=None) -> BridgeStats:
    """Analytic BridgeStats for moving one ``cube_nbytes`` message cube
    through a bridged program — exactly what :func:`simulate_bridged_program`
    counts (same arrival schedule, same FIFO machine, no data moved).
    ``tracer`` records the per-round ``bridge_tx``/``bridge_fifo``/
    ``bridge_stall`` events of that shared machine."""
    sim = _BridgeSim(bprog, tracer)
    for rnd in bprog.rounds:
        per = cube_nbytes // rnd.den
        for bidx in rnd.cross:
            sim.push(bidx, per)
        sim.end_round()
    return sim.finish()


# ---------------------------------------------------------------------------
# numpy round-by-round simulator (physical serialization, no devices)
# ---------------------------------------------------------------------------

def _np_wire_dtype(bits: int):
    return {8: np.uint8, 16: np.uint16, 32: np.uint32}[bits]


def _wire_roundtrip(seg: np.ndarray, br: _BridgeSim, bridge_idx: int) -> np.ndarray:
    """Physically serialize one crossing buffer: bytes → padded wire words
    (the beats on the narrow link) → bytes.  Lossless by construction; the
    round trip is what the far endpoint reconstructs."""
    s = br.cfg.serdes
    flat = np.ascontiguousarray(seg).reshape(-1)
    n_words = br.words_for(flat.nbytes)
    padded = np.zeros(n_words * s.beat_bytes, np.uint8)
    padded[:flat.nbytes] = flat
    words = padded.view(_np_wire_dtype(s.wire_bits))
    br.push(bridge_idx, flat.nbytes)
    back = words.view(np.uint8)[:flat.nbytes]
    return back.reshape(seg.shape)


def _np_line_bridged(buf: np.ndarray, phase, phys, pod_of, bridge_of,
                     br: _BridgeSim, stats: ScheduleStats) -> np.ndarray:
    """`routing._np_line_compiled` with the hop transport split at the cut.

    ``buf``: (m, m, R, k) — (axis holder, axis destination, physical row,
    payload bytes); ``phys(row, axis_pos)`` maps to the global node id, so
    each (s, d) hop of the axis perm becomes R physical link traversals."""
    m = phase.sched.size
    R = buf.shape[2]
    out = np.zeros_like(buf)
    for i in range(m):
        out[i, i] = buf[i, i]
    bufs = [buf.copy(), buf.copy()]
    for rnd in phase.rounds:
        stats.rounds += 1
        for mv in rnd.moves:
            cur = bufs[mv.buf]
            nxt = np.zeros_like(cur)
            for s, d in mv.perm:
                for r in range(R):
                    seg = cur[s, :, r]
                    sn, dn = phys(r, s), phys(r, d)
                    if pod_of[sn] != pod_of[dn]:
                        seg = _wire_roundtrip(seg, br, bridge_of[(sn, dn)])
                    nxt[d, :, r] = seg
                    stats.link_bytes += seg.nbytes
            bufs[mv.buf] = nxt
            for i in range(m):
                if mv.src_table[i] >= 0:
                    out[i, mv.src_table[i]] = bufs[mv.buf][i, i]
        br.end_round()
    return out


def simulate_bridged_program(bprog: BridgedProgram, msgs: np.ndarray, *,
                             batched: bool = False, tracer=None,
                             ) -> tuple[np.ndarray, ScheduleStats, BridgeStats]:
    """Round-by-round numpy execution of a partitioned program (no devices).

    msgs: (n_src, n_dst, *c) → (delivered (n_dst, n_src, *c), schedule stats,
    bridge stats).  Delivery and ScheduleStats are bit-identical to the
    unpartitioned `routing.simulate_route_program` — the cut is semantically
    transparent ("seamless" per the paper); only the BridgeStats record what
    the serial links did.  ``batched=True`` folds a leading batch axis into
    the payload (rounds counted once, bytes scale with B), mirroring
    `routing.simulate_schedule`."""
    if batched:
        assert msgs.ndim >= 3, "batched msgs must be (B, n_src, n_dst, *c)"
        inner = np.ascontiguousarray(np.moveaxis(msgs, 0, 2))
        delivered, stats, bstats = simulate_bridged_program(bprog, inner,
                                                            tracer=tracer)
        return (np.ascontiguousarray(np.moveaxis(delivered, 2, 0)), stats,
                bstats)
    prog = bprog.prog
    n = prog.n_nodes
    assert msgs.shape[0] == n and msgs.shape[1] == n
    pod_of = bprog.pod_of_node
    bridge_of = {(b.src, b.dst): i for i, b in enumerate(bprog.bridges)}
    stats = ScheduleStats()
    br = _BridgeSim(bprog, tracer)
    raw = np.ascontiguousarray(msgs)
    byte = raw.view(np.uint8).reshape(n, n, -1)
    k = byte.shape[2]

    def unview(b: np.ndarray) -> np.ndarray:
        return (np.ascontiguousarray(b).view(raw.dtype)
                .reshape((n, n) + raw.shape[2:]))

    if prog.fused:
        # single crossbar round: every (s, d) chunk crosses its link directly
        out = byte.swapaxes(0, 1).copy()
        stats.rounds = 1
        stats.link_bytes = int(byte.nbytes * (n - 1) / n)
        for (s, d), bidx in sorted(bridge_of.items()):
            out[d, s] = _wire_roundtrip(out[d, s], br, bidx)
        br.end_round()
        return unview(out), stats, br.finish()
    if len(prog.phases) == 1:
        out = _np_line_bridged(byte.reshape(n, n, 1, k), prog.phases[0],
                               lambda r, i: i, pod_of, bridge_of, br, stats)
        return unview(out.reshape(n, n, k)), stats, br.finish()
    # 2D XY routing — same factorized data motion as simulate_route_program,
    # with the physical row kept explicit so each hop splits at the cut
    (_, ry), (_, rx) = prog.axes
    phase_x, phase_y = prog.phases
    m = byte.reshape(ry, rx, ry, rx, k)
    b = np.moveaxis(m, (1, 3), (0, 1))              # [sx, dx, sy, dy, k]
    b = _np_line_bridged(np.ascontiguousarray(b).reshape(rx, rx, ry, -1),
                         phase_x, lambda r, x: r * rx + x,
                         pod_of, bridge_of, br, stats)
    b = b.reshape(rx, rx, ry, ry, k)                # [dx(node), sx, sy, dy, k]
    b = np.moveaxis(b, (2, 3), (0, 1))              # [sy, dy, dx, sx, k]
    b = _np_line_bridged(np.ascontiguousarray(b).reshape(ry, ry, rx, -1),
                         phase_y, lambda r, y: y * rx + r,
                         pod_of, bridge_of, br, stats)
    b = b.reshape(ry, ry, rx, rx, k)                # [dy(node), sy, dx, sx, k]
    out = np.moveaxis(b, (0, 2, 1, 3), (0, 1, 2, 3))
    return unview(np.ascontiguousarray(out).reshape(n, n, k)), stats, br.finish()


# ---------------------------------------------------------------------------
# shard_map lowering (device-mesh execution of the partitioned program)
# ---------------------------------------------------------------------------

def _bridged_transfer(bprog: BridgedProgram, axis_name):
    """Hop transport for `routing.run_route_program(transfer=...)`: intra-pod
    pairs stay one ppermute; cut pairs go through serdes endpoints — encode,
    ``lanes`` serialized beat ppermutes, decode (`serdes.send_over_link`,
    the grads_serdes machinery)."""
    pod_of = bprog.pod_of_node
    n = bprog.prog.n_nodes

    def transfer(buf, pairs):
        intra = [(s, d) for s, d in pairs if pod_of[s] == pod_of[d]]
        cross = [(s, d) for s, d in pairs if pod_of[s] != pod_of[d]]
        out = (lax.ppermute(buf, axis_name, intra) if intra
               else jnp.zeros_like(buf))
        if cross:
            rec, _ = qserdes.send_over_link(buf, axis_name, cross,
                                            bprog.wire_cfg, serialized=True)
            dst = np.zeros(n, bool)
            for _, d in cross:
                dst[d] = True
            i = lax.axis_index(axis_name)
            out = jnp.where(jnp.asarray(dst)[i], rec, out)
        return out

    return transfer


def _bridged_crossbar(x: jax.Array, bprog: BridgedProgram, axis_name) -> jax.Array:
    """Fat-tree/crossbar round split at the cut: intra chunks ride the fused
    all_to_all; cut chunks are serialized into wire words and the beats move
    through ``lanes`` separate all_to_alls before per-source decode."""
    n = bprog.prog.n_nodes
    pod_of = bprog.pod_of_node
    cross = np.array([[s != d and pod_of[s] != pod_of[d] for d in range(n)]
                      for s in range(n)])
    out = lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0)
    if not cross.any():
        return out
    cfg = bprog.wire_cfg
    meta = qserdes.plan(x.shape[1:], x.dtype, cfg)
    enc = jax.vmap(lambda row: qserdes.encode(row, cfg, meta)[0])(x)
    beats = [lax.all_to_all(enc[:, ln], axis_name, split_axis=0,
                            concat_axis=0)
             for ln in range(cfg.lanes)]
    words = jnp.stack(beats, axis=1)                # (n_src, lanes, w)
    zero_scales = jnp.zeros((cfg.lanes, 0), words.dtype)
    dec = jax.vmap(lambda w: qserdes.decode(w, zero_scales, cfg, meta))(words)
    i = lax.axis_index(axis_name)
    mask = jnp.asarray(cross)[:, i].reshape((n,) + (1,) * (x.ndim - 1))
    return jnp.where(mask, dec, out)


def run_bridged_program(x: jax.Array, bprog: BridgedProgram,
                        axis_name) -> jax.Array:
    """Execute a partitioned program inside ``shard_map``.

    Same per-device contract as `routing.run_route_program` — ``x`` is the
    ``(n, *chunk)`` destination-indexed view, returns the source-indexed
    received view — but always *linearized* over ``axis_name`` (a mesh axis
    name or tuple, e.g. ``("pod", "node")`` from `partition.mesh_for_partition`
    where the flat device index IS the global NoC node id).  Intra-pod hops
    are plain ppermute rounds; pod-crossing hops move through quasi-SERDES
    endpoints.  Bit-identical to the unpartitioned program by construction
    (the wire framing is lossless)."""
    if bprog.prog.fused:
        return _bridged_crossbar(x, bprog, axis_name)
    return run_route_program(x, bprog.prog, axis_name=axis_name,
                             transfer=_bridged_transfer(bprog, axis_name))
