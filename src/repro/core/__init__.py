"""Core library: the paper's contribution as composable JAX modules.

graph      — phase-1 message-passing application model (PEs, channels)
topology   — CONNECT-analog virtual topologies (ring/mesh/torus/fat-tree)
routing    — topology schedules as shard_map collectives + numpy simulator
serdes     — quasi-SERDES cut-link endpoints (framing + compression)
partition  — phase-2 placement, pod cutting, sharding rules, cross-pod sync
interchip  — bridge subsystem: compiled route programs across pod cuts
switch     — buffered wormhole switching: FIFOs, arbitration, backpressure
traffic    — synthetic traffic patterns (uniform/hotspot/transpose/bursty)
noc        — the executor + flit accounting (Tables I–V analogs)
"""
from .graph import PE, Channel, GraphError, Port, TaskGraph
from .interchip import (BridgeConfig, BridgedProgram, BridgeLink, BridgeStats,
                        PodProgram, bridge_program_stats, compile_bridges,
                        run_bridged_program, simulate_bridged_program)
from .noc import NoCConfig, NoCExecutor, NoCStats, wrapper_overhead
from .partition import (DEFAULT_RULES, PartitionPlan, candidate_cuts, constrain,
                        cross_pod_mean, cut, logical_to_spec, mesh_for_partition,
                        mesh_for_topology, named_sharding, node_device_coords,
                        optimize_placement, optimize_pod_cut, pair_cut_weights,
                        place_greedy, place_round_robin, placement_cost,
                        placement_to_device_coords, resolve_placement)
from .routing import (RouteProgram, all_to_all_for, compile_routes,
                      crossbar_all_to_all, grid_all_to_all, line_all_to_all,
                      ring_all_to_all_unidir, route_program_stats,
                      run_route_program, simulate_route_program,
                      simulate_schedule, topology_axes, transpose_oracle)
from .serdes import (LinkMeta, QuasiSerdesConfig, compression_ratio, decode, encode,
                     link_bytes_on_wire, link_wire_beats, plan, send_over_link)
from .switch import (DeadlockError, Packet, SwitchConfig, SwitchResult,
                     SwitchStats, dor_route, link_loads, saturation_rate,
                     simulate_switch, simulate_wormhole_cube,
                     switch_lower_bound)
from .traffic import (TrafficConfig, generate_traffic, traffic_matrix,
                      transpose_partner)
from .topology import (AxisSchedule, FatTree, Mesh2D, Ring, Topology, Torus2D,
                       bwd_pairs, compare, fwd_pairs, make_topology)

__all__ = [n for n in dir() if not n.startswith("_")]
