"""Buffered wormhole switching: cycle-accurate contention-aware NoC transport.

Every other execution mode in this repo (``direct``, ``sim``, ``spmd``, the
bridged variants) runs *contention-free* compiled schedules: all buffers move
in lock-step rounds, so input buffering, arbitration and backpressure are
inexpressible.  This module adds the congestion regime a real CONNECT-style
fabric lives in — the SpikeHard ``Router.v`` / zamlet ``NetworkSwitch`` model:

* **per-port input FIFOs** of configurable ``buffer_depth`` (flits), one per
  virtual channel, with credit-based backpressure (a flit advances only when
  the downstream FIFO has a free slot);
* **X-Y dimension-ordered routing** over the existing `core.topology` meshes
  and tori (unidirectional rotation on the ring, single-hop crossbar on the
  fat-tree) — minimal, static, never revisits a node;
* **round-robin arbitration** between the input (port, VC) slots competing for
  an output port — one flit per physical output per cycle, rotating priority,
  losers counted as ``arb_losses``;
* **packet-atomic (wormhole) switching per virtual channel**: a downstream VC
  FIFO is allocated to one packet from header to tail (``fifo`` owner), so a
  packet's flits are never interleaved with another packet's inside a VC,
  while the *physical* link is cycle-multiplexed between VCs (flit-level VC
  flow control — this is what keeps the escape channel live);
* **dateline virtual channels** on wrapped dimensions: packets start on VC 0
  and switch to VC 1 when they cross a wraparound link, which breaks the ring
  cyclic channel dependency — with ``n_vcs >= 2`` every supported topology's
  channel dependency graph is acyclic, hence deadlock-free (property-tested in
  tests/test_switch.py along with exactly-once delivery under saturation).

Two agreeing interpreters:

* :func:`simulate_switch` — the cycle simulator (numpy state tables, sparse
  per-cycle iteration over occupied FIFOs).  Terminates for every workload:
  each granted move strictly advances a flit along its static route, and a
  zero-move fixed point with flits in flight is reported as
  :class:`DeadlockError` instead of spinning.
* :func:`switch_lower_bound` / :func:`saturation_rate` — the analytic model:
  per-packet pipeline bound (``t_inject + hops + flits``), per-link and
  per-ejection-port serialization bounds, and the channel-load saturation
  rate.  The simulator can never beat the bound (property-tested) and matches
  it exactly in the contention-free and single-bottleneck regimes.

:func:`simulate_wormhole_cube` adapts the simulator to the executor's
``(n, n, buf_bytes)`` message-cube contract (`NoCExecutor` ``mode="buffered"``):
payload bytes physically ride the flits and ``delivered[d, s]`` is reassembled
from the flit tokens ejected at ``d`` — bit-identical to ``simulate_schedule``
delivery by the exactly-once property, not by construction.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional, Sequence

import numpy as np

from .topology import FatTree, Mesh2D, Ring, Topology, Torus2D

EJECT = -2    # output-port key: consume the flit at the local node
INJECT = -1   # input-port key: the node's (unbounded) injection queue


class DeadlockError(RuntimeError):
    """No flit can move, nothing left to inject: a cyclic resource wait."""


@dataclasses.dataclass(frozen=True)
class SwitchConfig:
    """CONNECT "Router Options" analog for the buffered mode.

    ``buffer_depth``  — input FIFO depth per (port, VC), in flits (SpikeHard's
                        ``BUFFER_DEPTH``); depth 1 is the legal worst case.
    ``n_vcs``         — virtual channels per input port; >= 2 required for
                        wrapped topologies (ring/torus datelines).
    ``flit_bytes``    — bytes carried per flit (== NoCConfig.flit_wire_bytes).
    ``max_cycles``    — optional hard horizon (DeadlockError past it); the
                        fixed-point detector makes it redundant for finite
                        workloads, it only guards mis-use.
    """

    buffer_depth: int = 4
    n_vcs: int = 2
    flit_bytes: int = 2
    max_cycles: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class Packet:
    """One wormhole packet: ``n_flits`` flits injected at ``t_inject``.

    ``payload`` (optional) is the uint8 byte vector the flits carry; flit
    ``f`` carries bytes ``[f*flit_bytes, (f+1)*flit_bytes)`` (zero-padded)."""

    src: int
    dst: int
    n_flits: int
    t_inject: int = 0
    payload: Optional[np.ndarray] = None


@dataclasses.dataclass
class SwitchStats:
    """Counters of one :func:`simulate_switch` run (NoCStats ``switch_*``)."""

    cycles: int = 0            # cycles until the last tail flit ejected
    packets: int = 0           # packets delivered (== offered, asserted)
    flits: int = 0             # flits ejected
    link_flits: int = 0        # flit-hops over router->router links
    stall_cycles: int = 0      # head flits blocked on credit/VC allocation
    arb_losses: int = 0        # eligible head flits that lost an arbitration
    max_queue: int = 0         # peak input-FIFO occupancy, flits
    peak_link_flits: int = 0   # peak flits crossing links in one cycle
    latency_sum: int = 0
    latency_max: int = 0

    @property
    def avg_latency(self) -> float:
        """Mean packet latency in cycles; 0.0 when nothing was delivered
        (a zero-packet workload must not divide by zero)."""
        if self.packets == 0:
            return 0.0
        return self.latency_sum / self.packets

    def throughput(self, n_nodes: int) -> float:
        """Accepted load over the whole run, flits/cycle/node; 0.0 for an
        empty run (zero cycles) or a degenerate node count."""
        if self.cycles <= 0 or n_nodes <= 0:
            return 0.0
        return self.flits / self.cycles / n_nodes


@dataclasses.dataclass
class SwitchResult:
    stats: SwitchStats
    completions: np.ndarray          # per-packet tail-eject cycle (exclusive)
    payloads: list                   # per-packet delivered bytes (or None)
    ejections: Optional[list] = None  # (cycle, packet_id) log when recorded


# ---------------------------------------------------------------------------
# X-Y dimension-ordered routing + dateline VC assignment
# ---------------------------------------------------------------------------

def dor_route(topo: Topology, src: int, dst: int,
              n_vcs: int = 2) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """Dimension-ordered route and per-hop virtual channels.

    Returns ``(route, vcs)``: ``route = (src, ..., dst)`` visits neighbors
    only and never revisits a node; ``vcs[i]`` is the VC of the input FIFO the
    packet occupies at ``route[i+1]`` (``len(vcs) == hops``).  VC 0 until the
    path crosses a wraparound (dateline) link in the current dimension, VC 1
    from that hop on; the VC resets to 0 when routing switches dimension
    (X links and Y links are disjoint channel sets).  Requires ``n_vcs >= 2``
    on wrapped topologies — with one VC the wrapped rings have a cyclic
    channel dependency and wormhole can deadlock."""
    if src == dst:
        return (src,), ()
    esc = min(1, n_vcs - 1)
    if isinstance(topo, FatTree):
        return (src, dst), (0,)
    if isinstance(topo, Ring):
        # paper-faithful CONNECT ring: unidirectional +1 rotation
        n = topo.n_nodes
        route, vcs, vc, cur = [src], [], 0, src
        while cur != dst:
            if cur == n - 1:          # the n-1 -> 0 hop crosses the dateline
                vc = esc
            cur = (cur + 1) % n
            route.append(cur)
            vcs.append(vc)
        return tuple(route), tuple(vcs)
    if isinstance(topo, Mesh2D):      # Torus2D is a subclass
        wrap = isinstance(topo, Torus2D)
        x, y = topo.coords(src)
        dx, dy = topo.coords(dst)
        route, vcs = [src], []
        for size, cur, tgt, axis in ((topo.rx, x, dx, "x"), (topo.ry, y, dy, "y")):
            vc = 0
            while cur != tgt:
                if wrap:
                    fwd = (tgt - cur) % size
                    step = 1 if fwd <= size - fwd else -1
                    if (cur == size - 1 and step == 1) or (cur == 0 and step == -1):
                        vc = esc      # this hop crosses the dimension dateline
                    cur = (cur + step) % size
                else:
                    cur += 1 if tgt > cur else -1
                if axis == "x":
                    x = cur
                else:
                    y = cur
                route.append(topo.node(x, y))
                vcs.append(vc)
        return tuple(route), tuple(vcs)
    raise TypeError(f"no dimension-ordered routes for {type(topo).__name__}")


# ---------------------------------------------------------------------------
# cycle simulator
# ---------------------------------------------------------------------------

def simulate_switch(topo: Topology, packets: Sequence[Packet],
                    cfg: Optional[SwitchConfig] = None,
                    record_ejections: bool = False,
                    verify: bool = True,
                    tracer=None) -> SwitchResult:
    """Cycle-accurate wormhole simulation of ``packets`` over ``topo``.

    Per cycle: every occupied input (port, VC) FIFO head requests its packet's
    next output; per physical output one flit is granted (owner VCs and
    credit-eligible headers compete, round-robin); grants are computed against
    start-of-cycle state and applied atomically, so the result is independent
    of router iteration order.  Raises :class:`DeadlockError` on a zero-move
    fixed point with flits in flight (exact: the state transition is
    deterministic, so one immobile cycle proves permanence).

    With ``verify=True`` (default) the (topology, n_vcs) combination is first
    proven deadlock-free via the channel-dependency graph of the routing
    function (`repro.analysis.cdg`); cyclic combinations are rejected up
    front with the concrete channel cycle.  ``verify=False`` skips the proof
    and lets doomed configurations run into the runtime `DeadlockError` —
    used by the verifier benchmarks and deadlock tests.

    ``tracer`` (a `repro.telemetry.Tracer`, optional) records one ``cycle``
    event per executed cycle (flit moves, link bytes, stall/arbitration
    deltas, ejections) plus ``queue`` occupancy counters, ``idle_ff``
    fast-forward markers and a ``deadlock`` instant before the error is
    raised; ``tracer.detail == "flits"`` adds one event per flit move.
    For attribution (`repro.telemetry.profile`) a traced run additionally
    emits one ``switch_run`` instant up front (packet/flit totals plus the
    analytic `switch_lower_bound`), one ``pkt`` instant per delivered packet
    at tail ejection (inject cycle, latency, hops, per-packet credit-stall
    and arbitration-loss counts) and per-link ``link`` byte counters at the
    end of the run.  Timestamps are ``tracer.clock + cycle``, so the caller
    positions the run on its timeline.  ``tracer=None`` adds no work to the
    loop."""
    cfg = cfg or SwitchConfig()
    n = topo.n_nodes
    depth = cfg.buffer_depth
    fb = cfg.flit_bytes
    if depth < 1:
        raise ValueError("buffer_depth must be >= 1")
    if cfg.n_vcs < 1:
        raise ValueError(f"n_vcs must be >= 1, got {cfg.n_vcs}")
    if verify:
        from ..analysis.cdg import check_deadlock_freedom

        found = check_deadlock_freedom(topo, cfg.n_vcs, "SwitchConfig.n_vcs")
        if found:
            raise ValueError(str(found[0]))

    # -- static per-packet tables ------------------------------------------
    P = len(packets)
    nxt: list[dict[int, tuple[int, int]]] = []   # node -> (out_key, down_vc)
    hops_of: list[int] = []
    pay_src: list[Optional[np.ndarray]] = []
    out_pay: list[Optional[np.ndarray]] = []
    for p in packets:
        if p.n_flits < 1:
            raise ValueError(f"packet {p.src}->{p.dst}: n_flits must be >= 1")
        route, vcs = dor_route(topo, p.src, p.dst, cfg.n_vcs)
        hops = len(route) - 1
        hops_of.append(hops)
        tab = {route[i]: (route[i + 1], vcs[i]) if i < hops else (EJECT, 0)
               for i in range(hops + 1)}
        nxt.append(tab)
        if p.payload is not None:
            buf = np.zeros(p.n_flits * fb, np.uint8)
            raw = np.ascontiguousarray(p.payload).reshape(-1).view(np.uint8)
            if raw.size > buf.size:
                raise ValueError(f"payload {raw.size}B exceeds "
                                 f"{p.n_flits} flits x {fb}B")
            buf[:raw.size] = raw
            pay_src.append(buf)
            out_pay.append(np.zeros_like(buf))
        else:
            pay_src.append(None)
            out_pay.append(None)

    # -- dynamic state ------------------------------------------------------
    # input FIFO key: (router, upstream_node | INJECT, vc)
    fifos: dict[tuple[int, int, int], deque] = {}
    owner: dict[tuple[int, int, int], Optional[int]] = {}
    srcq: dict[int, deque] = {s: deque() for s in range(n)}
    rr: dict[tuple[int, int], int] = {}
    # arbitration ring per router: injection slot first, then (port, vc) slots
    rings: list[list[tuple[int, int]]] = []
    for u in range(n):
        slots = [(INJECT, 0)]
        for up in sorted(topo.neighbors(u)):
            for vc in range(cfg.n_vcs):
                slots.append((up, vc))
        rings.append(slots)

    order = sorted(range(P), key=lambda i: (packets[i].t_inject, i))
    inj_ptr = 0
    stats = SwitchStats()
    base = tracer.clock if tracer is not None else 0
    flit_detail = tracer is not None and tracer.detail == "flits"
    # per-packet attribution state (traced runs only — the untraced loop
    # must stay allocation-free): credit/VC stall cycles and arbitration
    # losses charged to the packet at the head of the blocked FIFO, plus the
    # per-link flit tallies the heatmap / hot-link attribution read
    pkt_stall: Optional[list] = None
    pkt_arb: Optional[list] = None
    link_tally: Optional[dict] = None
    if tracer is not None and P:
        pkt_stall, pkt_arb, link_tally = [0] * P, [0] * P, {}
        tracer.instant("switch_run", "switch", ts=base, packets=P,
                       flits=sum(p.n_flits for p in packets),
                       bound=switch_lower_bound(topo, packets, cfg))
    t_stall0 = t_arb0 = t_ej0 = cyc_q = 0
    completions = np.full(P, -1, np.int64)
    ejected = np.zeros(P, np.int64)      # flits ejected so far, per packet
    ej_log: Optional[list] = [] if record_ejections else None
    c = 0
    while stats.packets < P:
        if cfg.max_cycles is not None and c > cfg.max_cycles:
            raise DeadlockError(f"max_cycles={cfg.max_cycles} exceeded with "
                                f"{P - stats.packets} packets in flight")
        injected = False
        while inj_ptr < P and packets[order[inj_ptr]].t_inject <= c:
            pid = order[inj_ptr]
            srcq[packets[pid].src].extend(
                (pid, f) for f in range(packets[pid].n_flits))
            inj_ptr += 1
            injected = True
        if tracer is not None:   # start-of-cycle baselines for event deltas
            t_stall0, t_arb0, t_ej0 = (stats.stall_cycles, stats.arb_losses,
                                       stats.flits)
            cyc_q = 0
        # ---- gather requests: head flit of every occupied input slot ------
        reqs: dict[tuple[int, int], list] = {}
        for u in range(n):
            for si, (up, vc) in enumerate(rings[u]):
                q = srcq[u] if up == INJECT else fifos.get((u, up, vc))
                if not q:
                    continue
                pid, fidx = q[0]
                okey, dvc = nxt[pid][u]
                if okey == EJECT:
                    elig = True
                else:
                    dkey = (okey, u, dvc)
                    own = owner.get(dkey)
                    df = fifos.get(dkey)
                    room = (0 if df is None else len(df)) < depth
                    # wormhole VC allocation: the downstream VC belongs to one
                    # packet header-to-tail; headers claim a free VC, body
                    # flits follow their claim — both need a credit
                    elig = room and (own == pid or (own is None and fidx == 0))
                reqs.setdefault((u, okey), []).append((si, up, vc, pid, fidx,
                                                       dvc, elig))
        # ---- arbitrate: one flit per physical output port per cycle -------
        moves = []
        for (u, okey), cands in sorted(reqs.items()):
            elig = [cand for cand in cands if cand[6]]
            stats.stall_cycles += len(cands) - len(elig)
            if pkt_stall is not None:
                for cand in cands:
                    if not cand[6]:
                        pkt_stall[cand[3]] += 1
            if not elig:
                continue
            ptr = rr.get((u, okey), 0)
            L = len(rings[u])
            win = min(elig, key=lambda cand: (cand[0] - ptr) % L)
            stats.arb_losses += len(elig) - 1
            if pkt_arb is not None:
                for cand in elig:
                    if cand is not win:
                        pkt_arb[cand[3]] += 1
            rr[(u, okey)] = (win[0] + 1) % L
            moves.append((u, okey, win))
        # ---- apply (grants were computed on start-of-cycle state) ---------
        link_moves = 0
        for u, okey, (si, up, vc, pid, fidx, dvc, _) in moves:
            pkt = packets[pid]
            tail = fidx == pkt.n_flits - 1
            if up == INJECT:
                srcq[u].popleft()
            else:
                fifos[(u, up, vc)].popleft()
                if tail:
                    owner[(u, up, vc)] = None
            if okey == EJECT:
                assert u == pkt.dst, (pid, u, pkt.dst)
                # wormhole keeps a packet's flits in order on one path:
                # in-order arrival here IS exactly-once delivery
                assert fidx == ejected[pid], (pid, fidx, int(ejected[pid]))
                ejected[pid] += 1
                stats.flits += 1
                if out_pay[pid] is not None:
                    out_pay[pid][fidx * fb:(fidx + 1) * fb] = \
                        pay_src[pid][fidx * fb:(fidx + 1) * fb]
                if ej_log is not None:
                    ej_log.append((c, pid))
                if tail:
                    stats.packets += 1
                    lat = c + 1 - pkt.t_inject
                    stats.latency_sum += lat
                    stats.latency_max = max(stats.latency_max, lat)
                    completions[pid] = c + 1
                    if tracer is not None:
                        tracer.instant(
                            "pkt", f"node {pkt.dst}", ts=base + c, pid=pid,
                            src=pkt.src, dst=pkt.dst, flits=pkt.n_flits,
                            hops=hops_of[pid], inject=pkt.t_inject, lat=lat,
                            stall=pkt_stall[pid], arb=pkt_arb[pid])
            else:
                dkey = (okey, u, dvc)
                dq = fifos.setdefault(dkey, deque())
                dq.append((pid, fidx))
                if fidx == 0:
                    owner[dkey] = pid
                link_moves += 1
                stats.link_flits += 1
                stats.max_queue = max(stats.max_queue, len(dq))
                if tracer is not None:
                    link_tally[(u, okey)] = link_tally.get((u, okey), 0) + 1
                    if len(dq) > cyc_q:
                        cyc_q = len(dq)
                    if flit_detail:
                        tracer.instant("flit", f"router {u}", ts=base + c,
                                       pid=pid, f=fidx, vc=vc, to=okey)
        stats.peak_link_flits = max(stats.peak_link_flits, link_moves)
        if not moves and not injected:
            if inj_ptr < P:   # idle gap: fast-forward to the next injection
                if tracer is not None:
                    tracer.instant("idle_ff", "switch", ts=base + c,
                                   to=packets[order[inj_ptr]].t_inject)
                c = packets[order[inj_ptr]].t_inject
                continue
            from ..analysis.cdg import find_wait_cycle

            stuck = [(pid, packets[pid].src, packets[pid].dst)
                     for pid in range(P) if completions[pid] < 0]
            # wait-for map over occupied input slots: each head flit points
            # at the downstream input FIFO it needs a credit/VC grant from
            waits: dict[tuple[int, int, int], tuple[int, int, int]] = {}
            for u in range(n):
                for up, vc in rings[u]:
                    q = srcq[u] if up == INJECT else fifos.get((u, up, vc))
                    if not q:
                        continue
                    pid, _ = q[0]
                    okey, dvc = nxt[pid][u]
                    if okey != EJECT:
                        waits[(u, up, vc)] = (okey, u, dvc)
            wcyc = find_wait_cycle(waits)
            culprit = ""
            if wcyc:
                hops = " -> ".join(
                    f"[router {r} <- {'inject' if up == INJECT else up} "
                    f"vc{vc}]" for r, up, vc in wcyc)
                culprit = (f"; culprit wait cycle across {len(wcyc)} "
                           f"router input(s): {hops} -> back to start")
            if tracer is not None:
                tracer.instant("deadlock", "switch", ts=base + c,
                               wedged=len(stuck),
                               wait_cycle=len(wcyc) if wcyc else 0)
            raise DeadlockError(
                f"cycle {c}: no flit can move, {len(stuck)} packets wedged "
                f"(first few: {stuck[:4]}) — cyclic buffer wait{culprit}")
        if tracer is not None:
            tracer.instant("cycle", "switch", ts=base + c, c=c,
                           moves=link_moves, bytes=link_moves * fb,
                           stalls=stats.stall_cycles - t_stall0,
                           arb=stats.arb_losses - t_arb0,
                           ejects=stats.flits - t_ej0)
            if cyc_q:
                tracer.counter("queue", "switch queue", cyc_q, ts=base + c)
        c += 1
    stats.cycles = c
    if link_tally:
        # end-of-run per-link totals: what the heatmap and the profiler's
        # hot-link attribution read for buffered runs (schedule transports
        # emit these per round; here one counter per traversed link)
        ts_end = base + max(c - 1, 0)
        for (u, v), flits in sorted(link_tally.items()):
            tracer.counter("link", f"link {u}->{v}", flits * fb, ts=ts_end)
    assert int(ejected.sum()) == sum(p.n_flits for p in packets)
    return SwitchResult(stats, completions, out_pay, ej_log)


# ---------------------------------------------------------------------------
# analytic model: lower bound + saturation
# ---------------------------------------------------------------------------

def link_loads(topo: Topology, packets: Sequence[Packet],
               n_vcs: int = 2) -> dict[tuple[int, int], int]:
    """Flits crossing each directed link under dimension-ordered routing."""
    loads: dict[tuple[int, int], int] = {}
    for p in packets:
        route, _ = dor_route(topo, p.src, p.dst, n_vcs)
        for i in range(len(route) - 1):
            key = (route[i], route[i + 1])
            loads[key] = loads.get(key, 0) + p.n_flits
    return loads


def switch_lower_bound(topo: Topology, packets: Sequence[Packet],
                       cfg: Optional[SwitchConfig] = None) -> int:
    """Exact lower bound on :func:`simulate_switch` drain cycles.

    max of three serialization arguments (each exact in its pure regime):

    * pipeline:  a packet's tail ejects no earlier than
      ``t_inject + hops + n_flits`` (one hop per cycle, one flit per cycle);
    * ejection:  node ``d`` ejects one flit per cycle, and the first flit for
      ``d`` cannot arrive before the minimum ``t_inject + hops`` over its
      senders — ``cycles >= lead + sum(flits to d)``;
    * link:      link ``(u, v)`` carries one flit per cycle; the first
      crossing happens no earlier than ``min(t_inject + pos_u)`` and the last
      crosser still needs ``min(hops - pos_u)`` cycles to eject.

    A single uncontended packet meets the bound with equality (tested), as
    does a single-bottleneck hotspot on the crossbar."""
    cfg = cfg or SwitchConfig()
    lb = 0
    eject: dict[int, list[int]] = {}          # dst -> [load, min_lead]
    links: dict[tuple[int, int], list[int]] = {}  # link -> [load, lead, trail]
    for p in packets:
        route, _ = dor_route(topo, p.src, p.dst, cfg.n_vcs)
        hops = len(route) - 1
        lb = max(lb, p.t_inject + hops + p.n_flits)
        e = eject.setdefault(p.dst, [0, p.t_inject + hops])
        e[0] += p.n_flits
        e[1] = min(e[1], p.t_inject + hops)
        for i in range(hops):
            rec = links.setdefault((route[i], route[i + 1]),
                                   [0, p.t_inject + i, hops - i])
            rec[0] += p.n_flits
            rec[1] = min(rec[1], p.t_inject + i)
            rec[2] = min(rec[2], hops - i)
    for load, lead in eject.values():
        lb = max(lb, lead + load)
    for load, lead, trail in links.values():
        lb = max(lb, lead + load + trail)
    return lb


def saturation_rate(topo: Topology, matrix: np.ndarray,
                    n_vcs: int = 2) -> float:
    """Analytic saturation injection rate, flits/cycle/node.

    ``matrix[s, d]`` is the fraction of node ``s``'s injected flits destined
    to ``d`` (rows sum to 1).  At per-node offered rate ``r`` the load on a
    channel is ``r * sum_{s,d} matrix[s,d] * [channel on route(s,d)]``; the
    network saturates when the most-loaded channel (link or ejection port,
    both 1 flit/cycle) reaches unity.  Measured accepted throughput can never
    exceed the returned rate (benchmark/property gate)."""
    n = topo.n_nodes
    matrix = np.asarray(matrix, np.float64)
    assert matrix.shape == (n, n)
    load: dict = {}
    for s in range(n):
        for d in range(n):
            w = float(matrix[s, d])
            if w <= 0.0:
                continue
            route, _ = dor_route(topo, s, d, n_vcs)
            for i in range(len(route) - 1):
                key = (route[i], route[i + 1])
                load[key] = load.get(key, 0.0) + w
            ekey = (EJECT, d)
            load[ekey] = load.get(ekey, 0.0) + w
    if not load:            # no traffic at all (e.g. single-node topology)
        return float("inf")
    return 1.0 / max(load.values())


# ---------------------------------------------------------------------------
# executor adapter: (n, n, buf_bytes) message-cube transport
# ---------------------------------------------------------------------------

def simulate_wormhole_cube(topo: Topology, msgs: np.ndarray,
                           cfg: Optional[SwitchConfig] = None,
                           pairs: Optional[Sequence[tuple[int, int, int]]] = None,
                           batched: bool = False,
                           tracer=None) -> tuple[np.ndarray, SwitchStats]:
    """Move one ``(n, n, buf_bytes)`` message cube through the buffered
    wormhole switch: same ``(delivered, stats)`` contract as
    :func:`routing.simulate_schedule` (``delivered[d, s] == msgs[s, d]``).

    ``pairs`` — optional ``(src, dst, nbytes)`` triples naming the occupied
    buffers (the executor passes each wave's compiled pair layout); by default
    every ``(s, d)`` buffer ships in full.  Each occupied buffer becomes ONE
    wormhole packet of ``ceil(nbytes / flit_bytes)`` flits, injected at cycle
    0 — a wave is a synchronized burst, the congested analog of one schedule
    execution.  With ``batched=True`` msgs carries a leading batch axis and
    the B message sets ride as payload inside the same packets (flit counts
    scale with B, as in the batched schedule simulator).

    Bytes physically ride the flits: delivery is reassembled from the ejected
    flit tokens, so the bit-identity with ``mode="sim"`` rests on the
    simulator's exactly-once property rather than on a transpose shortcut."""
    cfg = cfg or SwitchConfig()
    if batched:
        assert msgs.ndim >= 3, "batched msgs must be (B, n_src, n_dst, *c)"
        inner = np.ascontiguousarray(np.moveaxis(msgs, 0, 2))   # (n, n, B, buf)
        delivered, stats = simulate_wormhole_cube(topo, inner, cfg, pairs=pairs,
                                                  tracer=tracer)
        return np.ascontiguousarray(np.moveaxis(delivered, 2, 0)), stats
    n = topo.n_nodes
    assert msgs.shape[0] == n and msgs.shape[1] == n
    if pairs is None:
        pairs = [(s, d, msgs.shape[-1]) for s in range(n) for d in range(n)]
    packets, meta = [], []
    for s, d, nb in pairs:
        if nb <= 0:
            continue
        # cell is (..., buf) — (buf,) plain, (B, buf) via batched= recursion;
        # nb counts live bytes along the trailing buffer axis
        raw = np.ascontiguousarray(msgs[s, d][..., :nb]).reshape(-1)
        raw = raw.view(np.uint8)
        packets.append(Packet(s, d, max(1, -(-raw.size // cfg.flit_bytes)),
                              t_inject=0, payload=raw))
        meta.append((s, d, nb, raw.size))
    res = simulate_switch(topo, packets, cfg, tracer=tracer)
    delivered = np.zeros_like(msgs)
    for pid, (s, d, nb, size) in enumerate(meta):
        got = res.payloads[pid][:size]
        cell = delivered[d, s]
        cell[..., :nb] = got.reshape(cell.shape[:-1] + (nb,))
    return delivered, res.stats
