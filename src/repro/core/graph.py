"""Phase-1 of the paper: message-passing application model.

An application is expressed as a graph of *processing elements* (PEs) — pure
functions fired when all their input messages have arrived — connected by
typed, fixed-shape *channels*.  This mirrors the paper's Fig. 3: the PE body is
the "Data processing" module; the framework supplies the "Data collector"
(argument FIFOs + fire-when-complete) and "Data distributor" (result fan-out)
semantics.

The graph is a *static* dataflow description: shapes and dtypes of every
message are known a priori ("Storage requirements of both input and output
memory modules should be known a priori", §II-B-1).  That staticness is what
lets the same graph be (a) executed directly with jnp, (b) compiled onto a
topology routing schedule (core.routing), and (c) partitioned across pods
(core.partition).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class Port:
    """A typed endpoint of a PE.  shape/dtype are the message contract."""

    name: str
    shape: tuple[int, ...]
    dtype: Any = np.float32

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) * np.dtype(self.dtype).itemsize

    def spec(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


@dataclasses.dataclass(frozen=True)
class PE:
    """A processing element: ``outputs = fn(**inputs)``.

    ``fn`` maps keyword args (one per input port, jnp arrays of the declared
    shape) to a dict keyed by output-port name.  It must be pure and
    jit-compatible; the framework owns all communication.
    """

    name: str
    fn: Callable[..., Mapping[str, Any]]
    inputs: tuple[Port, ...]
    outputs: tuple[Port, ...]

    def in_port(self, name: str) -> Port:
        for p in self.inputs:
            if p.name == name:
                return p
        raise KeyError(f"PE {self.name!r} has no input port {name!r}")

    def out_port(self, name: str) -> Port:
        for p in self.outputs:
            if p.name == name:
                return p
        raise KeyError(f"PE {self.name!r} has no output port {name!r}")


@dataclasses.dataclass(frozen=True)
class Channel:
    """A directed message channel ``src_pe.src_port -> dst_pe.dst_port``."""

    src_pe: str
    src_port: str
    dst_pe: str
    dst_port: str

    def key(self) -> tuple[str, str, str, str]:
        return (self.src_pe, self.src_port, self.dst_pe, self.dst_port)


class GraphError(ValueError):
    pass


class TaskGraph:
    """A static dataflow graph of PEs.

    Graph-level inputs are PE input ports nobody writes; graph-level outputs
    are PE output ports nobody reads (both may be overridden explicitly).
    """

    def __init__(self, name: str = "app"):
        self.name = name
        self.pes: dict[str, PE] = {}
        self.channels: list[Channel] = []

    # -- construction -------------------------------------------------------
    def add(self, pe: PE) -> PE:
        if pe.name in self.pes:
            raise GraphError(f"duplicate PE name {pe.name!r}")
        self.pes[pe.name] = pe
        return pe

    def connect(self, src: str, dst: str) -> Channel:
        """``connect("pe_a.out", "pe_b.x")``"""
        src_pe, src_port = src.split(".")
        dst_pe, dst_port = dst.split(".")
        sp = self.pes[src_pe].out_port(src_port)
        dp = self.pes[dst_pe].in_port(dst_port)
        if sp.shape != dp.shape or np.dtype(sp.dtype) != np.dtype(dp.dtype):
            raise GraphError(
                f"channel {src} -> {dst}: contract mismatch "
                f"{sp.shape}/{np.dtype(sp.dtype)} vs {dp.shape}/{np.dtype(dp.dtype)}"
            )
        ch = Channel(src_pe, src_port, dst_pe, dst_port)
        self.channels.append(ch)
        return ch

    # -- analysis -----------------------------------------------------------
    def validate(self) -> None:
        seen: set[tuple[str, str]] = set()
        for ch in self.channels:
            k = (ch.dst_pe, ch.dst_port)
            if k in seen:
                raise GraphError(f"input port {ch.dst_pe}.{ch.dst_port} written twice")
            seen.add(k)

    def graph_inputs(self) -> list[tuple[str, Port]]:
        fed = {(c.dst_pe, c.dst_port) for c in self.channels}
        out = []
        for pe in self.pes.values():
            for p in pe.inputs:
                if (pe.name, p.name) not in fed:
                    out.append((pe.name, p))
        return out

    def graph_outputs(self) -> list[tuple[str, Port]]:
        read = {(c.src_pe, c.src_port) for c in self.channels}
        out = []
        for pe in self.pes.values():
            for p in pe.outputs:
                if (pe.name, p.name) not in read:
                    out.append((pe.name, p))
        return out

    def firing_order(self) -> list[str]:
        """Topological order of PEs (data-flow firing schedule).

        Raises GraphError on cycles — iterative apps (LDPC) are expressed as a
        graph per iteration plus an outer ``lax.scan`` / ``run_iterative``.
        """
        self.validate()
        preds: dict[str, set[str]] = {n: set() for n in self.pes}
        succs: dict[str, set[str]] = {n: set() for n in self.pes}
        for c in self.channels:
            if c.src_pe != c.dst_pe:
                preds[c.dst_pe].add(c.src_pe)
                succs[c.src_pe].add(c.dst_pe)
        ready = sorted(n for n, p in preds.items() if not p)
        order: list[str] = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            for s in sorted(succs[n]):
                preds[s].discard(n)
                if not preds[s]:
                    ready.append(s)
        if len(order) != len(self.pes):
            cyc = sorted(set(self.pes) - set(order))
            raise GraphError(f"graph has a cycle through {cyc}")
        return order

    def traffic_bytes(self) -> dict[tuple[str, str], int]:
        """Bytes moved per (src_pe, dst_pe) pair — input to placement/roofline."""
        out: dict[tuple[str, str], int] = {}
        for c in self.channels:
            b = self.pes[c.src_pe].out_port(c.src_port).nbytes
            k = (c.src_pe, c.dst_pe)
            out[k] = out.get(k, 0) + b
        return out

    # -- direct (single-device) execution ------------------------------------
    def run(self, inputs: Mapping[str, Any]) -> dict[str, Any]:
        """Execute the dataflow directly with jnp (the pure-software oracle).

        ``inputs`` / result are keyed ``"pe.port"``.  This is the reference
        semantics every distributed execution mode must match.
        """
        order = self.firing_order()
        mailbox: dict[tuple[str, str], Any] = {}
        for k, v in inputs.items():
            pe_name, port = k.split(".")
            self.pes[pe_name].in_port(port)  # contract check
            mailbox[(pe_name, port)] = v
        by_src: dict[str, list[Channel]] = {n: [] for n in self.pes}
        for c in self.channels:
            by_src[c.src_pe].append(c)
        for name in order:
            pe = self.pes[name]
            kwargs = {}
            for p in pe.inputs:
                if (name, p.name) not in mailbox:
                    raise GraphError(f"PE {name!r} fired with missing input {p.name!r}")
                kwargs[p.name] = mailbox[(name, p.name)]
            results = pe.fn(**kwargs)
            missing = {p.name for p in pe.outputs} - set(results)
            if missing:
                raise GraphError(f"PE {name!r} did not produce outputs {sorted(missing)}")
            for p in pe.outputs:
                mailbox[(name, p.name)] = results[p.name]
            # deliver along outgoing channels (Data Distributor semantics)
            for c in by_src[name]:
                mailbox[(c.dst_pe, c.dst_port)] = mailbox[(name, c.src_port)]
        return {f"{pe}.{port.name}": mailbox[(pe, port.name)] for pe, port in self.graph_outputs()}

    def run_iterative(self, inputs: Mapping[str, Any], feedback: Sequence[tuple[str, str]],
                      n_iters: int) -> dict[str, Any]:
        """Run the graph ``n_iters`` times, feeding ``feedback`` pairs
        (``"pe.out" -> "pe.in"``) from one iteration into the next.
        Used for iterative message-passing apps (LDPC decoding)."""
        state = dict(inputs)
        outs: dict[str, Any] = {}
        for _ in range(n_iters):
            outs = self.run(state)
            for src, dst in feedback:
                state[dst] = outs[src]
        return outs

    def __repr__(self) -> str:
        return f"TaskGraph({self.name!r}, pes={len(self.pes)}, channels={len(self.channels)})"
