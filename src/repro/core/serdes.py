"""Quasi-SERDES link endpoints (paper §III, Fig. 6) — TPU adaptation.

On the FPGAs, an NoC link cut by the chip partition is replaced by a pair of
endpoints that serialize each flit over a handful of GPIO pins ("8 bits at a
time, MSB first") and reconstruct it on the far side.  The TPU analog of a
pin-starved link is the cross-pod DCN hop (~an order of magnitude slower than
ICI), so the endpoint here does what narrow links demand:

  * framing   — messages are packed into fixed-width flit words (+pad), and
                optionally transferred in ``n_lanes`` serialized chunks
                (paper-faithful serialization) or one shot (optimized);
  * narrowing — optional lossy compression (bf16 cast, or int8 block
                quantization with error feedback) so fewer "pins" carry the
                same message — the distributed-optimization payoff.

``encode``/``decode`` are exact inverses for mode="none"/"bf16" (up to the
bf16 rounding applied once), and quantization error is bounded and killed over
steps by error feedback for mode="int8" (property tests in
tests/test_serdes.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class QuasiSerdesConfig:
    """wire_bits: width of the physical flit word put on the link per beat.
    lanes: number of serialized beats a message is split into (1 = one shot).
    compress: 'none' | 'bf16' | 'int8'.
    block: quantization block size for int8 (per-block scale)."""

    wire_bits: int = 16
    lanes: int = 8
    compress: str = "none"
    block: int = 256

    def __post_init__(self):
        assert self.wire_bits in (8, 16, 32)
        assert self.compress in ("none", "bf16", "int8")
        assert self.lanes >= 1

    @property
    def beat_bytes(self) -> int:
        """Storage bytes of ONE wire word (a single-lane beat) — the same
        ceiling-division framing rule as ``NoCConfig.flit_wire_bytes``.  All
        word↔byte arithmetic in this module goes through here."""
        return -(-self.wire_bits // 8)

    def trace_args(self) -> dict:
        """Link description stamped on telemetry ``bridge_cfg`` events, so a
        trace is self-describing about the wire format it was recorded on."""
        return {"wire_bits": self.wire_bits, "lanes": self.lanes,
                "beat_bytes": self.beat_bytes, "compress": self.compress}


@dataclasses.dataclass
class LinkMeta:
    """Static metadata both endpoints agree on a priori (the paper requires
    storage requirements known a priori — same deal)."""

    shape: tuple[int, ...]
    dtype: Any
    n_words: int  # payload words of wire_bits each, incl. padding
    n_scale_words: int = 0


def _wire_dtype(bits: int):
    return {8: jnp.uint8, 16: jnp.uint16, 32: jnp.uint32}[bits]


def _pad_to(x: jax.Array, multiple: int) -> jax.Array:
    pad = (-x.shape[0]) % multiple
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return x


def plan(shape: tuple[int, ...], dtype, cfg: QuasiSerdesConfig) -> LinkMeta:
    """Compute the static framing plan for a message contract."""
    n = int(math.prod(shape)) if shape else 1
    wire_bytes = cfg.beat_bytes
    if cfg.compress == "none":
        payload = n * jnp.dtype(dtype).itemsize
        scale_words = 0
    elif cfg.compress == "bf16":
        payload = n * 2
        scale_words = 0
    else:  # int8
        payload = n
        n_blocks = -(-n // cfg.block)
        scale_words = -(-n_blocks * 4 // wire_bytes)  # f32 scale per block
    n_words = -(-payload // wire_bytes)
    # pad words so they split evenly into lanes
    n_words = -(-n_words // cfg.lanes) * cfg.lanes
    scale_words = -(-scale_words // cfg.lanes) * cfg.lanes if scale_words else 0
    return LinkMeta(tuple(shape), jnp.dtype(dtype), n_words, scale_words)


def _bitcast_to_words(x: jax.Array, bits: int) -> jax.Array:
    wd = _wire_dtype(bits)
    flat = x.reshape(-1)
    b = lax.bitcast_convert_type(flat, jnp.uint8).reshape(-1)
    b = _pad_to(b, bits // 8)
    return lax.bitcast_convert_type(b.reshape(-1, bits // 8), wd).reshape(-1)


def _words_to_bitcast(w: jax.Array, shape, dtype, bits: int) -> jax.Array:
    nbytes = int(math.prod(shape)) * jnp.dtype(dtype).itemsize
    b = lax.bitcast_convert_type(w, jnp.uint8).reshape(-1)[:nbytes]
    item = jnp.dtype(dtype).itemsize
    return lax.bitcast_convert_type(b.reshape(-1, item), dtype).reshape(shape)


def encode(x: jax.Array, cfg: QuasiSerdesConfig, meta: LinkMeta,
           residual: Optional[jax.Array] = None):
    """→ (words[(lanes, n_words//lanes)], scale_words, new_residual).

    residual: error-feedback accumulator (int8 mode); pass the previous step's
    value, keep the returned one.
    """
    wd = _wire_dtype(cfg.wire_bits)
    scale_words = jnp.zeros((max(cfg.lanes, 1), max(meta.n_scale_words // max(cfg.lanes, 1), 0)), wd) \
        if meta.n_scale_words else jnp.zeros((cfg.lanes, 0), wd)
    new_residual = residual
    if cfg.compress == "none":
        words = _bitcast_to_words(x, cfg.wire_bits)
    elif cfg.compress == "bf16":
        words = _bitcast_to_words(x.astype(jnp.bfloat16), cfg.wire_bits)
    else:  # int8 block quantization + error feedback
        flat = x.astype(jnp.float32).reshape(-1)
        if residual is not None:
            flat = flat + residual
        padded = _pad_to(flat, cfg.block).reshape(-1, cfg.block)
        scale = jnp.max(jnp.abs(padded), axis=1, keepdims=True) / 127.0
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(padded / safe), -127, 127).astype(jnp.int8)
        deq = (q.astype(jnp.float32) * scale).reshape(-1)[: flat.shape[0]]
        new_residual = flat - deq
        words = _bitcast_to_words(q.reshape(-1).view(jnp.int8), cfg.wire_bits)
        sw = _bitcast_to_words(scale.reshape(-1), cfg.wire_bits)
        sw = _pad_to(sw, max(meta.n_scale_words, cfg.lanes))[: meta.n_scale_words]
        scale_words = sw.reshape(cfg.lanes, -1)
    words = _pad_to(words, meta.n_words)[: meta.n_words]
    return words.reshape(cfg.lanes, -1), scale_words, new_residual


def decode(words: jax.Array, scale_words: jax.Array, cfg: QuasiSerdesConfig,
           meta: LinkMeta) -> jax.Array:
    n = int(math.prod(meta.shape)) if meta.shape else 1
    flat_words = words.reshape(-1)
    if cfg.compress == "none":
        return _words_to_bitcast(flat_words, meta.shape, meta.dtype, cfg.wire_bits)
    if cfg.compress == "bf16":
        nbytes = n * 2
        b = lax.bitcast_convert_type(flat_words, jnp.uint8).reshape(-1)[:nbytes]
        bf = lax.bitcast_convert_type(b.reshape(-1, 2), jnp.bfloat16).reshape(meta.shape)
        return bf.astype(meta.dtype)
    # int8: first n bytes are the real quantized payload; re-pad to whole blocks
    b = lax.bitcast_convert_type(flat_words, jnp.uint8).reshape(-1)[:n]
    b = _pad_to(b, cfg.block)
    q = lax.bitcast_convert_type(b.reshape(-1, 1), jnp.int8).reshape(-1, cfg.block)
    sb = lax.bitcast_convert_type(scale_words.reshape(-1), jnp.uint8).reshape(-1)
    n_blocks = q.shape[0]
    scale = lax.bitcast_convert_type(sb[: n_blocks * 4].reshape(-1, 4), jnp.float32).reshape(-1, 1)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return deq.reshape(meta.shape).astype(meta.dtype)


# ---------------------------------------------------------------------------
# link transfer (inside shard_map, across the cut axis)
# ---------------------------------------------------------------------------

def send_over_link(x: jax.Array, axis_name: str, perm: list[tuple[int, int]],
                   cfg: QuasiSerdesConfig, meta: Optional[LinkMeta] = None,
                   residual: Optional[jax.Array] = None, serialized: bool = True):
    """Move ``x`` across the cut (e.g. pod↔pod) through quasi-SERDES endpoints.

    serialized=True sends the ``lanes`` beats as separate ppermutes — the
    paper-faithful "8 bits at a time" behavior (lets XLA pipeline/overlap each
    beat with compute); False sends the whole frame at once (optimized).
    Returns (received, new_residual).
    """
    meta = meta or plan(x.shape, x.dtype, cfg)
    words, scales, new_res = encode(x, cfg, meta, residual)
    if serialized:
        beats = [lax.ppermute(words[i], axis_name, perm) for i in range(cfg.lanes)]
        rwords = jnp.stack(beats)
    else:
        rwords = lax.ppermute(words, axis_name, perm)
    rscales = lax.ppermute(scales, axis_name, perm) if meta.n_scale_words else scales
    return decode(rwords, rscales, cfg, meta), new_res


def link_wire_beats(shape, dtype, cfg: QuasiSerdesConfig) -> int:
    """Serialized wire beats (padded words incl. scale words) one message of
    this contract occupies on a cut link — ``lanes`` × per-lane words.  The
    serdes-aware cut weight used by ``partition.placement_cost`` and the
    pod-cut co-optimizer, so the annealer and the co-optimizer share one
    objective."""
    meta = plan(tuple(shape), dtype, cfg)
    return meta.n_words + meta.n_scale_words


def link_bytes_on_wire(shape, dtype, cfg: QuasiSerdesConfig) -> int:
    """Bytes that actually cross the narrow link (roofline collective term)."""
    return link_wire_beats(shape, dtype, cfg) * cfg.beat_bytes


def compression_ratio(shape, dtype, cfg: QuasiSerdesConfig) -> float:
    raw = int(math.prod(shape)) * jnp.dtype(dtype).itemsize
    return raw / max(1, link_bytes_on_wire(shape, dtype, cfg))
