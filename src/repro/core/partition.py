"""Phase-2 of the paper: partitioning the NoC across chips (here: pods).

Three layers, mirroring §III:

1. **Placement** — map TaskGraph PEs onto topology nodes (the paper does this
   manually; we provide round-robin and a greedy traffic-aware placer).
2. **Cutting** — given a node→pod assignment, classify every channel as
   intra-pod (stays an on-chip NoC link) or cross-pod (gets a pair of
   quasi-SERDES endpoints stitched in, `core.serdes`).  The executor consumes
   this; the application is oblivious ("seamless" per the paper).
3. **Mesh sharding rules** — the LM-framework generalization: logical array
   axes → mesh axes (MaxText-style), plus the cross-pod collective that
   replaces XLA's flat all-reduce with a hierarchical, optionally
   serdes-compressed exchange over the cut.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Mapping, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import serdes as qserdes
from .graph import Channel, TaskGraph
from .topology import Mesh2D, Topology


# ---------------------------------------------------------------------------
# 1. placement
# ---------------------------------------------------------------------------

def place_round_robin(graph: TaskGraph, topo: Topology) -> dict[str, int]:
    names = list(graph.pes)
    return {n: i % topo.n_nodes for i, n in enumerate(names)}


def place_greedy(graph: TaskGraph, topo: Topology) -> dict[str, int]:
    """Traffic-aware: place heavy-talking PE pairs on low-hop node pairs.

    Classic greedy: order PE pairs by traffic desc; for each, put the unplaced
    endpoint on the free node closest to the placed one.
    """
    traffic = graph.traffic_bytes()
    pairs = sorted(traffic.items(), key=lambda kv: -kv[1])
    placement: dict[str, int] = {}
    free = set(range(topo.n_nodes))

    def nearest_free(anchor: int) -> int:
        if not free:
            # more PEs than nodes: fall back to min-load node
            loads: dict[int, int] = {}
            for v in placement.values():
                loads[v] = loads.get(v, 0) + 1
            return min(range(topo.n_nodes), key=lambda n: loads.get(n, 0))
        return min(free, key=lambda n: topo.hops(anchor, n))

    for (a, b), _ in pairs:
        if a not in placement and b not in placement:
            na = min(free) if free else 0
            placement[a] = na
            free.discard(na)
            nb = nearest_free(na)
            placement[b] = nb
            free.discard(nb)
        elif a in placement and b not in placement:
            nb = nearest_free(placement[a])
            placement[b] = nb
            free.discard(nb)
        elif b in placement and a not in placement:
            na = nearest_free(placement[b])
            placement[a] = na
            free.discard(na)
    for n in graph.pes:  # isolated PEs
        if n not in placement:
            node = min(free) if free else 0
            placement[n] = node
            free.discard(node)
    return placement


def pair_cut_weights(graph: TaskGraph,
                     serdes_cfg: qserdes.QuasiSerdesConfig) -> dict[tuple[str, str], int]:
    """Per (src_pe, dst_pe) pair: the serialized wire beats its channels
    occupy when the pair lands across the pod cut (`serdes.link_wire_beats` —
    padded words incl. scale words, == lanes × per-lane words)."""
    out: dict[tuple[str, str], int] = {}
    for c in graph.channels:
        p = graph.pes[c.src_pe].out_port(c.src_port)
        w = qserdes.link_wire_beats(p.shape, p.dtype, serdes_cfg)
        k = (c.src_pe, c.dst_pe)
        out[k] = out.get(k, 0) + w
    return out


def placement_cost(graph: TaskGraph, topo: Topology, placement: Mapping[str, int],
                   pod_of_node: Optional[Sequence[int]] = None,
                   serdes_cfg: Optional[qserdes.QuasiSerdesConfig] = None,
                   w_cut: float = 1.0) -> float:
    """The placement objective, shared by the greedy placer, the annealer and
    the pod-cut co-optimizer (one objective, no disagreement):

    * intra-pod edges (and all edges when no cut is given) cost
      ``traffic_bytes × hops`` — on-chip link traffic;
    * pod-crossing edges cost ``w_cut ×`` their **serialized wire beats**
      (`pair_cut_weights`) — serdes-aware: a cut edge pays for the padded
      words its messages occupy on the narrow link (compression and lane
      padding included), not its raw byte count.
    """
    traffic = graph.traffic_bytes()
    if pod_of_node is None:
        return sum(b * topo.hops(placement[a], placement[c])
                   for (a, c), b in traffic.items())
    beats = pair_cut_weights(graph, serdes_cfg or qserdes.QuasiSerdesConfig())
    cost = 0.0
    for (a, c), b in traffic.items():
        if pod_of_node[placement[a]] == pod_of_node[placement[c]]:
            cost += b * topo.hops(placement[a], placement[c])
        else:
            cost += w_cut * beats[(a, c)]
    return cost


def optimize_placement(graph: TaskGraph, topo: Topology,
                       pod_of_node: Optional[Sequence[int]] = None,
                       init: Optional[Mapping[str, int]] = None,
                       iters: int = 2000, seed: int = 0,
                       w_cut: float = 1.0,
                       max_per_node: Optional[int] = None,
                       serdes_cfg: Optional[qserdes.QuasiSerdesConfig] = None,
                       ) -> dict[str, int]:
    """Annealing/KL-style placement search (the paper places by hand; this is
    the automated analog).

    Minimizes :func:`placement_cost`: Σ traffic × hops for on-chip edges,
    plus — when a node→pod assignment is given — ``w_cut`` × the serialized
    wire beats of every edge crossing the pod cut (serdes-aware, so the
    annealer and the pod-cut co-optimizer share one objective; each cut edge
    pays for the quasi-SERDES frame its messages occupy).  Moves are single
    PE relocations and PE↔PE swaps; acceptance is simulated annealing with a
    geometric cooling schedule, deterministic under ``seed``.  Incremental
    delta evaluation touches only the moved PEs' channels, so a step is O(deg)
    not O(E) — cheap enough to run per app graph at executor-build time.

    ``max_per_node`` caps router occupancy (the paper's NoC wraps one PE per
    router); default is the balanced occupancy ``ceil(n_pes / n_nodes)`` — 1
    when PEs fit — so the search cannot game the hop objective by stacking
    every PE on one node.
    """
    rng = np.random.default_rng(seed)
    names = list(graph.pes)
    n = topo.n_nodes
    if max_per_node is None:
        max_per_node = -(-len(names) // n)

    def occupancy(p):
        o: dict[int, int] = {}
        for node in p.values():
            o[node] = o.get(node, 0) + 1
        return o

    if init is not None:
        placement = dict(init)
    else:
        # greedy seed when it respects capacity; round-robin (always balanced)
        # otherwise — greedy's both-unplaced fallback can stack node 0 when
        # PEs far outnumber nodes
        placement = place_greedy(graph, topo)
        if max(occupancy(placement).values(), default=0) > max_per_node:
            placement = place_round_robin(graph, topo)
    occ = occupancy(placement)
    if max(occ.values(), default=0) > max_per_node:
        raise ValueError(f"initial placement exceeds max_per_node={max_per_node}: "
                         f"occupancy {occ}")
    # symmetric traffic adjacency: pe -> [(other_pe, bytes, cut wire beats)]
    beats = pair_cut_weights(graph, serdes_cfg or qserdes.QuasiSerdesConfig())
    adj: dict[str, list[tuple[str, int, int]]] = {p: [] for p in names}
    for (a, b), by in graph.traffic_bytes().items():
        if a != b:
            adj[a].append((b, by, beats[(a, b)]))
            adj[b].append((a, by, beats[(a, b)]))

    def local(pe: str, node: int) -> float:
        c = 0.0
        for other, by, cw in adj[pe]:
            o = node if other == pe else placement[other]
            if pod_of_node is not None and pod_of_node[node] != pod_of_node[o]:
                c += w_cut * cw
            else:
                c += by * topo.hops(node, o)
        return c

    def total() -> float:
        return float(placement_cost(graph, topo, placement, pod_of_node,
                                    serdes_cfg, w_cut))

    cost = total()
    best_cost, best = cost, dict(placement)
    t0 = max(cost / max(len(names), 1), 1.0)
    t_end = t0 / 1000.0
    for it in range(iters):
        temp = t0 * (t_end / t0) ** (it / max(iters - 1, 1))
        if rng.random() < 0.5 or len(names) < 2:
            # relocate one PE to a random node with free capacity
            pe = names[int(rng.integers(len(names)))]
            old_node = placement[pe]
            new_node = int(rng.integers(n))
            if new_node == old_node or occ.get(new_node, 0) >= max_per_node:
                continue
            before = local(pe, old_node)
            placement[pe] = new_node
            delta = local(pe, new_node) - before
            if delta <= 0 or rng.random() < np.exp(-delta / temp):
                cost += delta
                occ[old_node] -= 1
                occ[new_node] = occ.get(new_node, 0) + 1
            else:
                placement[pe] = old_node
        else:
            # swap two PEs' nodes (KL-style exchange)
            i, j = rng.choice(len(names), size=2, replace=False)
            p, q = names[int(i)], names[int(j)]
            np_, nq = placement[p], placement[q]
            if np_ == nq:
                continue
            before = local(p, np_) + local(q, nq)
            placement[p], placement[q] = nq, np_
            delta = (local(p, nq) + local(q, np_)) - before
            if delta <= 0 or rng.random() < np.exp(-delta / temp):
                cost += delta
            else:
                placement[p], placement[q] = np_, nq
        if cost < best_cost - 1e-9:
            best_cost, best = cost, dict(placement)
    return best


def resolve_placement(graph: TaskGraph, topo: Topology, spec="rr",
                      pod_of_node: Optional[Sequence[int]] = None,
                      seed: int = 0,
                      serdes_cfg: Optional[qserdes.QuasiSerdesConfig] = None,
                      ) -> dict[str, int]:
    """Turn a placement spec into a PE→node map.

    ``spec`` is one of ``"rr"`` (round-robin), ``"greedy"``, ``"opt"``
    (annealing search, see :func:`optimize_placement` — cut-aware when
    ``pod_of_node`` is given, weighting cut edges by ``serdes_cfg``'s
    serialized wire beats so the search optimizes the objective the executor
    actually pays) or an explicit mapping, which is passed through."""
    if isinstance(spec, Mapping):
        missing = set(graph.pes) - set(spec)
        if missing:
            raise ValueError(f"placement mapping is missing PEs {sorted(missing)}")
        bad = {p: n for p, n in spec.items() if not 0 <= n < topo.n_nodes}
        if bad:
            raise ValueError(f"placement mapping has out-of-range nodes {bad} "
                             f"(topology has {topo.n_nodes} nodes)")
        return dict(spec)
    if spec == "rr":
        return place_round_robin(graph, topo)
    if spec == "greedy":
        return place_greedy(graph, topo)
    if spec == "opt":
        return optimize_placement(graph, topo, pod_of_node=pod_of_node, seed=seed,
                                  serdes_cfg=serdes_cfg)
    raise ValueError(f"unknown placement spec {spec!r}; use 'rr'|'greedy'|'opt' or a mapping")


# ---------------------------------------------------------------------------
# 1b. placement → device-mesh assignment (SPMD execution of the placed graph)
# ---------------------------------------------------------------------------

def mesh_for_topology(topo: Topology, devices: Optional[Sequence] = None) -> Mesh:
    """Build the device mesh a topology's compiled routing schedule runs over.

    Mesh axes follow ``core.routing.topology_axes`` (1D ``noc`` axis for
    ring/fat-tree, ``(noc_y, noc_x)`` for mesh/torus), so NoC node ``i`` is
    device ``i`` in mesh row-major order — the identity the spmd executor and
    :func:`node_device_coords` rely on."""
    from .routing import topology_axes

    axes = topology_axes(topo)
    shape = [s for _, s in axes]
    need = int(np.prod(shape, dtype=np.int64))
    devices = list(jax.devices()) if devices is None else list(devices)
    if len(devices) < need:
        raise RuntimeError(
            f"topology {topo.name!r} needs {need} devices for SPMD execution, "
            f"have {len(devices)}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    return Mesh(np.array(devices[:need]).reshape(shape),
                tuple(a for a, _ in axes))


def mesh_for_partition(topo: Topology, plan: "PartitionPlan",
                       devices: Optional[Sequence] = None) -> Mesh:
    """Device mesh for *partitioned* spmd execution (`core.interchip`).

    When the plan's pods are equal-sized contiguous node blocks, the mesh is
    2D ``(pod, node)`` — pod p owns devices ``[p*k, (p+1)*k)`` and the flat
    linearized device index over ``("pod", "node")`` is exactly the global
    NoC node id the bridged program's hop pairs use.  For irregular cuts the
    topology mesh is returned instead (pod membership then lives only in the
    bridge tables; the execution is identical because the bridged program is
    always linearized over the flat index)."""
    n = topo.n_nodes
    pods = tuple(plan.pod_of_node)
    n_pods = max(pods) + 1 if pods else 1
    blocked = (n_pods > 1 and n % n_pods == 0
               and all(pods[i] == i // (n // n_pods) for i in range(n)))
    if not blocked:
        return mesh_for_topology(topo, devices)
    devices = list(jax.devices()) if devices is None else list(devices)
    if len(devices) < n:
        raise RuntimeError(
            f"topology {topo.name!r} needs {n} devices for partitioned SPMD "
            f"execution, have {len(devices)}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    return Mesh(np.array(devices[:n]).reshape(n_pods, n // n_pods),
                ("pod", "node"))


def node_device_coords(topo: Topology, node: int) -> dict[str, int]:
    """Linear NoC node id → mesh-axis coordinates on ``mesh_for_topology``."""
    from .topology import Mesh2D

    if not 0 <= node < topo.n_nodes:
        raise ValueError(f"node {node} out of range for {topo.n_nodes}-node topology")
    if isinstance(topo, Mesh2D):
        x, y = topo.coords(node)
        return {"noc_y": y, "noc_x": x}
    return {"noc": node}


def placement_to_device_coords(placement: Mapping[str, int],
                               topo: Topology) -> dict[str, dict[str, int]]:
    """Map a PE→node placement (e.g. an ``optimize_placement`` result) onto
    device coordinates of the SPMD mesh — which device each PE's messages
    originate from when the schedule runs as a real collective program."""
    return {pe: node_device_coords(topo, node) for pe, node in placement.items()}


# ---------------------------------------------------------------------------
# 2. cutting across pods
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Result of cutting a placed graph across pods (paper Fig. 5)."""

    placement: Mapping[str, int]          # PE -> node
    pod_of_node: tuple[int, ...]          # node -> pod
    intra: tuple[Channel, ...]
    cross: tuple[Channel, ...]            # channels that get serdes endpoints
    serdes_cfg: qserdes.QuasiSerdesConfig = qserdes.QuasiSerdesConfig()

    @property
    def n_pods(self) -> int:
        return max(self.pod_of_node) + 1 if self.pod_of_node else 1

    def cut_bytes(self, graph: TaskGraph) -> int:
        return sum(graph.pes[c.src_pe].out_port(c.src_port).nbytes for c in self.cross)

    def wire_beats(self, graph: TaskGraph) -> int:
        """Serialized wire beats (padded words incl. scale words) the cut
        channels occupy per wave — the serdes-aware cut cost the placement
        objective charges (`placement_cost` / `optimize_pod_cut`)."""
        return sum(
            qserdes.link_wire_beats(
                graph.pes[c.src_pe].out_port(c.src_port).shape,
                graph.pes[c.src_pe].out_port(c.src_port).dtype,
                self.serdes_cfg,
            )
            for c in self.cross
        )

    def wire_bytes(self, graph: TaskGraph) -> int:
        """Bytes on the narrow inter-pod wire after serdes framing/compression
        (= ``wire_beats × beat_bytes`` — one framing rule, one call site)."""
        return self.wire_beats(graph) * self.serdes_cfg.beat_bytes


def cut(graph: TaskGraph, placement: Mapping[str, int], pod_of_node: Sequence[int],
        serdes_cfg: qserdes.QuasiSerdesConfig = qserdes.QuasiSerdesConfig()) -> PartitionPlan:
    intra, cross = [], []
    for c in graph.channels:
        same = pod_of_node[placement[c.src_pe]] == pod_of_node[placement[c.dst_pe]]
        (intra if same else cross).append(c)
    return PartitionPlan(dict(placement), tuple(pod_of_node), tuple(intra), tuple(cross), serdes_cfg)


def candidate_cuts(topo: Topology, n_pods: int) -> list[tuple[int, ...]]:
    """Deterministic node→pod candidates for an ``n_pods``-way cut:

    * linear blocks (rows of a 2D grid, arcs of a ring) — the physical
      "consecutive routers per chip" split;
    * column blocks for 2D topologies (cut along the other axis);
    * strided round-robin — the adversarial control the optimizer should
      beat on locality-sensitive graphs.
    """
    n = topo.n_nodes
    cands: list[tuple[int, ...]] = []
    if n % n_pods == 0:
        blk = n // n_pods
        cands.append(tuple(i // blk for i in range(n)))
        if isinstance(topo, Mesh2D) and topo.rx % n_pods == 0:
            w = topo.rx // n_pods
            cands.append(tuple((i % topo.rx) // w for i in range(n)))
        cands.append(tuple(i % n_pods for i in range(n)))
    else:
        cands.append(tuple(min(i * n_pods // n, n_pods - 1) for i in range(n)))
    seen, out = set(), []
    for c in cands:
        if c not in seen:
            seen.add(c)
            out.append(c)
    return out


def optimize_pod_cut(graph: TaskGraph, topo: Topology, n_pods: int = 2,
                     serdes_grid: Optional[Sequence[qserdes.QuasiSerdesConfig]] = None,
                     iters: int = 800, seed: int = 0,
                     w_cut: float = 1.0) -> tuple[PartitionPlan, float]:
    """Co-optimize the pod cut with serdes compression settings (the ROADMAP
    placement/pod-cut item): for every candidate node→pod cut
    (:func:`candidate_cuts`) × serdes config in ``serdes_grid``, anneal the
    placement under the shared serdes-aware objective
    (:func:`placement_cost` = intra-pod link bytes + serialized cut beats)
    and keep the winner.  Deterministic under ``seed``.

    Returns ``(PartitionPlan, cost)`` — the plan carries the chosen
    placement, pod assignment and serdes config, ready for
    ``NoCExecutor(plan=...)``."""
    if serdes_grid is None:
        serdes_grid = [qserdes.QuasiSerdesConfig(wire_bits=wb, lanes=ln, compress=cp)
                       for wb in (8, 16, 32) for ln in (1, 8)
                       for cp in ("none", "bf16")]
    best: Optional[tuple[float, dict, tuple, qserdes.QuasiSerdesConfig]] = None
    for pods in candidate_cuts(topo, n_pods):
        for scfg in serdes_grid:
            pl = optimize_placement(graph, topo, pod_of_node=pods, iters=iters,
                                    seed=seed, w_cut=w_cut, serdes_cfg=scfg)
            c = float(placement_cost(graph, topo, pl, pods, scfg, w_cut))
            if best is None or c < best[0]:
                best = (c, pl, pods, scfg)
    cost, pl, pods, scfg = best
    return cut(graph, pl, pods, scfg), cost


# ---------------------------------------------------------------------------
# 3. LM-framework sharding rules + cross-pod collectives
# ---------------------------------------------------------------------------

# Logical axis vocabulary used by every model in src/repro/models.
DEFAULT_RULES: dict[str, Optional[str | tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_kv_shard": "data",      # long-context KV/state sequence sharding
    "head_dim_shard": "data",    # long-context KV head_dim sharding (decode:
                                 #   DUS stays shard-local; QK psums over data)
    "embed": None,               # d_model stays replicated-per-shard (activations)
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "conv": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "layers": None,              # scanned-stack leading axis
}


@contextlib.contextmanager
def rules_override(**kv):
    """Temporarily rewrite DEFAULT_RULES entries (e.g. no_tp: model axes off).
    Used by the hillclimb to evaluate sharding-profile changes per cell."""
    saved = {k: DEFAULT_RULES.get(k) for k in kv}
    DEFAULT_RULES.update(kv)
    try:
        yield
    finally:
        DEFAULT_RULES.update(saved)


NO_TP = dict(vocab=None, heads=None, kv_heads=None, mlp=None, experts=None,
             ssm_inner=None, batch=("pod", "data", "model"))


def logical_to_spec(axes: Sequence[Optional[str]], rules: Mapping[str, Any] = DEFAULT_RULES,
                    mesh_axes: Optional[Sequence[str]] = None,
                    dims: Optional[Sequence[int]] = None,
                    mesh_shape: Optional[Mapping[str, int]] = None) -> P:
    """('batch','seq','embed') -> PartitionSpec(('pod','data'), None, None).

    Drops mesh axes absent from the current mesh (single-pod drops 'pod'),
    and — when ``dims``/``mesh_shape`` are given — axes whose product does not
    divide the array dimension (e.g. 8 KV heads on a model=16 axis fall back
    to replication rather than failing)."""
    parts = []
    for i, a in enumerate(axes):
        m = rules.get(a) if a is not None else None
        if m is None:
            parts.append(None)
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        if mesh_axes is not None:
            ms = tuple(x for x in ms if x in mesh_axes)
        if dims is not None and mesh_shape is not None and ms:
            keep, prod = [], 1
            for x in ms:
                nx = mesh_shape.get(x, 1)
                if dims[i] % (prod * nx) == 0:
                    keep.append(x)
                    prod *= nx
            ms = tuple(keep)
        parts.append(ms[0] if len(ms) == 1 else (ms if ms else None))
    return P(*parts)


def named_sharding(mesh: Mesh, axes: Sequence[Optional[str]],
                   rules: Mapping[str, Any] = DEFAULT_RULES) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(axes, rules, mesh.axis_names))


def constrain(x: jax.Array, axes: Sequence[Optional[str]],
              rules: Mapping[str, Any] = DEFAULT_RULES) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op outside jit/mesh);
    shape-aware: unshardable dims stay replicated."""
    try:
        from ..compat import MODERN_SHARD_MAP, get_abstract_mesh, manual_axes_in_scope
        mesh = get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        manual = manual_axes_in_scope()
        if manual and not MODERN_SHARD_MAP:
            return x  # constraint hints inside partial-manual regions crash old XLA
        usable = tuple(a for a in mesh.axis_names if a not in manual)
        if not usable:
            return x
        spec = logical_to_spec(axes, rules, usable, dims=x.shape,
                               mesh_shape=dict(mesh.shape))
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


# -- cross-pod gradient exchange (the "cut link" of the LM framework) --------

def cross_pod_mean(tree, axis: str = "pod", cfg: Optional[qserdes.QuasiSerdesConfig] = None,
                   residuals=None, n_pods: int = 2, serialized: bool = True):
    """Average a pytree over the pod axis *inside shard_map*.

    cfg=None      → plain ``lax.pmean`` (XLA flat collective; baseline).
    cfg given     → paper-faithful: each pod serializes its contribution
                    through quasi-SERDES endpoints over the cut links
                    (ring exchange over pods), with optional compression and
                    error-feedback residuals.
    Returns (tree, new_residuals).
    """
    if cfg is None:
        return jax.tree.map(lambda g: lax.pmean(g, axis), tree), residuals

    perm = [(i, (i + 1) % n_pods) for i in range(n_pods)]

    def sync_leaf(g, res):
        acc = g
        send = g
        r = res
        for _ in range(n_pods - 1):
            recv, r = qserdes.send_over_link(send, axis, perm, cfg, residual=r,
                                             serialized=serialized)
            acc = acc + recv
            send = recv  # forward the neighbor's contribution around the ring
        return acc / n_pods, r

    leaves, treedef = jax.tree.flatten(tree)
    res_leaves = (jax.tree.flatten(residuals)[0] if residuals is not None
                  else [None] * len(leaves))
    out, new_res = [], []
    for g, r in zip(leaves, res_leaves):
        o, nr = sync_leaf(g, r)
        out.append(o)
        new_res.append(nr if nr is not None else jnp.zeros_like(g, jnp.float32)
                       if cfg.compress == "int8" else 0.0)
    return jax.tree.unflatten(treedef, out), jax.tree.unflatten(treedef, new_res)
