"""Virtual network topologies (CONNECT analog).

The paper generates a packet-switched NoC of a chosen topology (ring, mesh,
torus, fat-tree — Table V) from CONNECT.  On TPU there is no programmer-visible
packet switching, so a Topology here compiles to a *static schedule* of
neighbor exchanges (`core.routing` executes it with ``lax.ppermute`` /
``lax.all_to_all`` under ``shard_map``) plus an analytic cost model
(rounds × bytes/round, hop counts) that powers the Table-V-style topology
comparison and the roofline collective term.

Cost model conventions
----------------------
*Round*: one synchronous neighbor-exchange step; every node may send one
buffer over each of its links (bidirectional links = 2 concurrent transfers).
For an all-to-all of per-destination chunks of ``c`` bytes over ``n`` nodes:

  ring(n)      rounds = n - 1 (unidirectional rotation; chunks in transit
               shrink each round)                      link-bytes ≈ c·n(n−1)/2
  mesh(rx,ry)  factorized line-a2a per dim, bidirectional, no wraparound:
               rounds = (rx−1) + (ry−1)
  torus(rx,ry) factorized ring-a2a per dim, bidirectional wraparound:
               rounds = ⌈rx/2⌉ + ⌈ry/2⌉
  fat-tree     ideal full-bisection crossbar: 1 round (fused all_to_all)

This reproduces the paper's observed ordering ring < mesh < torus < fat-tree.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable


# ---------------------------------------------------------------------------
# neighbor permutation tables — the (src, dst) pairs of one synchronous hop
# along a 1D axis.  These are the raw material of the schedule→ppermute
# compiler (`core.routing.compile_routes`): every routing round is one of
# these permutations applied to a rotating buffer.
# ---------------------------------------------------------------------------

def fwd_pairs(n: int, wrap: bool) -> tuple[tuple[int, int], ...]:
    """One +1 hop: node s forwards its buffer to s+1 (wraparound optional)."""
    return tuple((s, (s + 1) % n) for s in range(n) if wrap or s + 1 < n)


def bwd_pairs(n: int, wrap: bool) -> tuple[tuple[int, int], ...]:
    """One -1 hop: node s forwards its buffer to s-1 (wraparound optional)."""
    return tuple((s, (s - 1) % n) for s in range(n) if wrap or s - 1 >= 0)


@dataclasses.dataclass(frozen=True)
class AxisSchedule:
    """Hop-decomposition spec of an all-to-all along one mesh axis.

    ``axis``   — mesh axis name the exchange runs over (shard_map axis);
    ``size``   — number of nodes along the axis;
    ``wrap``   — wraparound links exist (ring/torus dimension);
    ``unidir`` — rotate one direction only (the paper-faithful CONNECT ring
                 routers forward a single direction).
    """

    axis: str
    size: int
    wrap: bool
    unidir: bool = False

    @property
    def fwd_steps(self) -> int:
        if self.unidir:
            return self.size - 1
        return self.size // 2 if self.wrap else self.size - 1

    @property
    def bwd_steps(self) -> int:
        if self.unidir:
            return 0
        return (self.size - 1) // 2 if self.wrap else self.size - 1

    def fwd_pairs(self) -> tuple[tuple[int, int], ...]:
        return fwd_pairs(self.size, self.wrap)

    def bwd_pairs(self) -> tuple[tuple[int, int], ...]:
        return bwd_pairs(self.size, self.wrap)


@dataclasses.dataclass(frozen=True)
class Topology:
    """Base class; subclasses define connectivity and schedule cost."""

    n_nodes: int

    @property
    def name(self) -> str:
        return type(self).__name__.lower()

    # -- connectivity --------------------------------------------------------
    def neighbors(self, node: int) -> tuple[int, ...]:
        raise NotImplementedError

    def hops(self, src: int, dst: int) -> int:
        raise NotImplementedError

    def avg_hops(self) -> float:
        n = self.n_nodes
        tot = sum(self.hops(s, d) for s in range(n) for d in range(n) if s != d)
        return tot / (n * (n - 1))

    def bisection_links(self) -> int:
        raise NotImplementedError

    # -- schedule spec -------------------------------------------------------
    def axis_schedules(self) -> tuple[AxisSchedule, ...]:
        """Per-axis hop decomposition of this topology's all-to-all.

        Dimension-ordered (XY) routing: phases run in the returned order, one
        line/ring exchange per axis.  An empty tuple means the topology is an
        ideal crossbar (single fused exchange, no hop decomposition)."""
        raise NotImplementedError

    # -- schedule cost -------------------------------------------------------
    def a2a_rounds(self) -> int:
        """Neighbor-exchange rounds for a full all-to-all personalized exchange."""
        raise NotImplementedError

    def a2a_link_bytes(self, chunk_bytes: int) -> int:
        """Total bytes crossing links for an all-to-all of per-dest chunks."""
        n = self.n_nodes
        # sum over (src,dst) pairs of hops(src,dst) * chunk
        tot = sum(self.hops(s, d) for s in range(n) for d in range(n) if s != d)
        return tot * chunk_bytes

    def a2a_time_model(self, chunk_bytes: int, link_bw: float, hop_latency: float) -> float:
        """Simple alpha-beta model: rounds*latency + serialized link traffic."""
        links = max(1, self.n_links())
        return self.a2a_rounds() * hop_latency + self.a2a_link_bytes(chunk_bytes) / (links * link_bw)

    def n_links(self) -> int:
        return sum(len(self.neighbors(i)) for i in range(self.n_nodes)) // 2

    def validate(self) -> None:
        for i in range(self.n_nodes):
            for j in self.neighbors(i):
                assert i in self.neighbors(j), f"asymmetric link {i}->{j}"


@dataclasses.dataclass(frozen=True)
class Ring(Topology):
    def neighbors(self, node: int) -> tuple[int, ...]:
        n = self.n_nodes
        return ((node - 1) % n, (node + 1) % n)

    def hops(self, src: int, dst: int) -> int:
        n = self.n_nodes
        d = abs(src - dst)
        return min(d, n - d)

    def bisection_links(self) -> int:
        return 2

    def axis_schedules(self) -> tuple[AxisSchedule, ...]:
        return (AxisSchedule("noc", self.n_nodes, wrap=True, unidir=True),)

    def a2a_rounds(self) -> int:
        # unidirectional systolic rotation (paper-faithful: CONNECT ring routers
        # forward one direction); n-1 rounds.
        return self.n_nodes - 1


def _factor2d(n: int) -> tuple[int, int]:
    rx = int(math.sqrt(n))
    while n % rx:
        rx -= 1
    return rx, n // rx


@dataclasses.dataclass(frozen=True)
class Mesh2D(Topology):
    rx: int = 0
    ry: int = 0

    def __post_init__(self):
        if self.rx == 0:
            rx, ry = _factor2d(self.n_nodes)
            object.__setattr__(self, "rx", rx)
            object.__setattr__(self, "ry", ry)
        assert self.rx * self.ry == self.n_nodes

    def coords(self, node: int) -> tuple[int, int]:
        return node % self.rx, node // self.rx

    def node(self, x: int, y: int) -> int:
        return y * self.rx + x

    def neighbors(self, node: int) -> tuple[int, ...]:
        x, y = self.coords(node)
        out = []
        if x > 0:
            out.append(self.node(x - 1, y))
        if x < self.rx - 1:
            out.append(self.node(x + 1, y))
        if y > 0:
            out.append(self.node(x, y - 1))
        if y < self.ry - 1:
            out.append(self.node(x, y + 1))
        return tuple(out)

    def hops(self, src: int, dst: int) -> int:
        sx, sy = self.coords(src)
        dx, dy = self.coords(dst)
        return abs(sx - dx) + abs(sy - dy)

    def bisection_links(self) -> int:
        return min(self.rx, self.ry)

    def axis_schedules(self) -> tuple[AxisSchedule, ...]:
        # XY dimension-ordered routing: phase X first, then Y
        wrap = isinstance(self, Torus2D)
        return (AxisSchedule("noc_x", self.rx, wrap=wrap),
                AxisSchedule("noc_y", self.ry, wrap=wrap))

    def a2a_rounds(self) -> int:
        # dimension-ordered, bidirectional line exchange per dim
        return (self.rx - 1) + (self.ry - 1)


@dataclasses.dataclass(frozen=True)
class Torus2D(Mesh2D):
    def neighbors(self, node: int) -> tuple[int, ...]:
        x, y = self.coords(node)
        return tuple(
            {
                self.node((x - 1) % self.rx, y),
                self.node((x + 1) % self.rx, y),
                self.node(x, (y - 1) % self.ry),
                self.node(x, (y + 1) % self.ry),
            }
            - {node}
        )

    def hops(self, src: int, dst: int) -> int:
        sx, sy = self.coords(src)
        dx, dy = self.coords(dst)
        hx = min(abs(sx - dx), self.rx - abs(sx - dx))
        hy = min(abs(sy - dy), self.ry - abs(sy - dy))
        return hx + hy

    def bisection_links(self) -> int:
        return 2 * min(self.rx, self.ry)

    def a2a_rounds(self) -> int:
        return math.ceil(self.rx / 2) + math.ceil(self.ry / 2)


@dataclasses.dataclass(frozen=True)
class FatTree(Topology):
    """Modeled as an ideal full-bisection crossbar (CONNECT's fat tree at the
    radix used in the paper); compiles to one fused ``lax.all_to_all``."""

    def neighbors(self, node: int) -> tuple[int, ...]:
        return tuple(i for i in range(self.n_nodes) if i != node)

    def hops(self, src: int, dst: int) -> int:
        return 1 if src != dst else 0

    def bisection_links(self) -> int:
        return self.n_nodes // 2

    def n_links(self) -> int:
        # full-bisection: n/2 concurrent disjoint paths
        return self.n_nodes // 2

    def axis_schedules(self) -> tuple[AxisSchedule, ...]:
        return ()   # ideal crossbar: one fused exchange, no hop decomposition

    def a2a_rounds(self) -> int:
        return 1


TOPOLOGIES = {"ring": Ring, "mesh": Mesh2D, "torus": Torus2D, "fattree": FatTree,
              # class-name aliases (MoE configs use the explicit 2D names)
              "mesh2d": Mesh2D, "torus2d": Torus2D}


def make_topology(name: str, n_nodes: int) -> Topology:
    try:
        return TOPOLOGIES[name](n_nodes)
    except KeyError:
        raise ValueError(f"unknown topology {name!r}; choose from {sorted(TOPOLOGIES)}")


def compare(n_nodes: int, chunk_bytes: int, names: Iterable[str] = ("ring", "mesh", "torus", "fattree"),
            link_bw: float = 50e9, hop_latency: float = 1e-6) -> list[dict]:
    """Table-V-style analytic comparison."""
    rows = []
    for name in names:
        t = make_topology(name, n_nodes)
        rows.append(
            dict(
                topology=name,
                nodes=n_nodes,
                rounds=t.a2a_rounds(),
                links=t.n_links(),
                avg_hops=round(t.avg_hops(), 3),
                bisection_links=t.bisection_links(),
                a2a_link_bytes=t.a2a_link_bytes(chunk_bytes),
                model_time_us=round(t.a2a_time_model(chunk_bytes, link_bw, hop_latency) * 1e6, 3),
            )
        )
    return rows
