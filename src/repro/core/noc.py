"""NoC executor: run a TaskGraph over a Topology, optionally cut across pods.

This is the integration point of the framework (paper Fig. 1): PEs from
phase-1 (`core.graph`) are placed on a CONNECT-style topology
(`core.topology`), messages move via the topology's routing schedule
(`core.routing`), and cut links go through quasi-SERDES endpoints
(`core.serdes` via `core.partition`).

Execution modes
---------------
* ``direct``  — `TaskGraph.run`; the pure-software oracle (the paper's
  "multithreaded message passing software version").
* ``sim``     — fires PEs wave-by-wave and physically moves every message
  round-by-round through the topology schedule (numpy).  Produces the
  NoCStats used by the Table-IV/V-style benchmarks, and — by construction —
  bit-identical outputs to ``direct`` (tested).

Flit accounting mirrors CONNECT's link model (default flit_data_width=16,
the paper's BMVM NoC config) and powers the Tables I–III "with/without
wrapper" overhead analogs: on TPU the wrapper cost is not LUTs/registers but
the padding + framing + buffer bytes the NoC abstraction adds around the raw
message payload.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional

import numpy as np

from . import serdes as qserdes
from .graph import TaskGraph
from .partition import PartitionPlan
from .routing import ScheduleStats, simulate_schedule
from .topology import Topology


@dataclasses.dataclass
class NoCStats:
    waves: int = 0
    rounds: int = 0
    link_bytes: int = 0
    payload_bytes: int = 0
    flits: int = 0
    cross_pod_msgs: int = 0
    cross_pod_wire_bytes: int = 0
    cross_pod_beats: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class NoCConfig:
    """CONNECT "Network and Router Options" analog (paper §VI-B)."""

    flit_data_width: int = 16          # bits
    flit_buffer_depth: int = 8         # capacity factor analog for MoE dispatch
    serdes: qserdes.QuasiSerdesConfig = qserdes.QuasiSerdesConfig()

    def flits_for(self, nbytes: int) -> int:
        per = self.flit_data_width // 8
        return -(-nbytes // per)


def wrapper_overhead(graph: TaskGraph, cfg: NoCConfig = NoCConfig()) -> list[dict]:
    """Tables I–III analog: per-PE cost without vs with the NoC wrapper.

    'wo_wrapper_bytes'  — the PE's raw argument/result bytes (the bare module);
    'fifo_bytes'        — Data Collector/Distributor FIFO storage;
    'flit_bytes'        — framed on-link size incl. padding to flit width;
    'overhead'          — (with - without) / without, the Table-I ratio.
    """
    rows = []
    for pe in graph.pes.values():
        in_b = sum(p.nbytes for p in pe.inputs)
        out_b = sum(p.nbytes for p in pe.outputs)
        raw = in_b + out_b
        fifo = cfg.flit_buffer_depth * cfg.flit_data_width // 8 * (len(pe.inputs) + len(pe.outputs))
        flit_b = sum(cfg.flits_for(p.nbytes) * cfg.flit_data_width // 8
                     for p in list(pe.inputs) + list(pe.outputs))
        rows.append(dict(pe=pe.name, wo_wrapper_bytes=raw, fifo_bytes=fifo,
                         flit_bytes=flit_b, with_wrapper_bytes=flit_b + fifo,
                         overhead=round((flit_b + fifo - raw) / max(raw, 1), 3)))
    return rows


class NoCExecutor:
    def __init__(self, graph: TaskGraph, topo: Topology,
                 placement: Optional[Mapping[str, int]] = None,
                 plan: Optional[PartitionPlan] = None,
                 cfg: NoCConfig = NoCConfig()):
        from .partition import place_round_robin

        self.graph = graph
        self.topo = topo
        self.placement = dict(placement or (plan.placement if plan else place_round_robin(graph, topo)))
        self.plan = plan
        self.cfg = cfg
        graph.validate()
        self._order = graph.firing_order()
        # group PEs into waves by dataflow depth
        depth: dict[str, int] = {}
        preds: dict[str, set[str]] = {n: set() for n in graph.pes}
        for c in graph.channels:
            if c.src_pe != c.dst_pe:
                preds[c.dst_pe].add(c.src_pe)
        for n in self._order:
            depth[n] = 1 + max((depth[p] for p in preds[n]), default=-1)
        self.waves: list[list[str]] = []
        for n in self._order:
            while len(self.waves) <= depth[n]:
                self.waves.append([])
            self.waves[depth[n]].append(n)

    # ------------------------------------------------------------------
    def run(self, inputs: Mapping[str, Any], mode: str = "sim") -> tuple[dict[str, Any], NoCStats]:
        if mode == "direct":
            return self.graph.run(inputs), NoCStats()
        assert mode == "sim"
        g, topo, cfg = self.graph, self.topo, self.cfg
        stats = NoCStats()
        mailbox: dict[tuple[str, str], Any] = {}
        for k, v in inputs.items():
            pe, port = k.split(".")
            mailbox[(pe, port)] = np.asarray(v)

        chan_by_src: dict[str, list] = {n: [] for n in g.pes}
        for c in g.channels:
            chan_by_src[c.src_pe].append(c)

        pod_of = None
        if self.plan is not None:
            pod_of = self.plan.pod_of_node

        for wave in self.waves:
            stats.waves += 1
            # fire
            outbox: list[tuple[Any, int, int, str, str]] = []  # (val, src_node, dst_node, dst_pe, dst_port)
            for name in wave:
                pe = g.pes[name]
                kwargs = {p.name: mailbox[(name, p.name)] for p in pe.inputs}
                results = pe.fn(**kwargs)
                for p in pe.outputs:
                    mailbox[(name, p.name)] = np.asarray(results[p.name])
                for c in chan_by_src[name]:
                    val = np.asarray(results[c.src_port])
                    outbox.append((val, self.placement[c.src_pe], self.placement[c.dst_pe],
                                   c.dst_pe, c.dst_port))
            if not outbox:
                continue
            # frame messages into per-(src,dst) flit buffers and route them
            n = topo.n_nodes
            per_pair: dict[tuple[int, int], list] = {}
            for val, s, d, dpe, dport in outbox:
                per_pair.setdefault((s, d), []).append((val, dpe, dport))
                stats.payload_bytes += val.nbytes
                stats.flits += cfg.flits_for(val.nbytes)
                if pod_of is not None and pod_of[s] != pod_of[d]:
                    stats.cross_pod_msgs += 1
                    stats.cross_pod_wire_bytes += qserdes.link_bytes_on_wire(
                        val.shape, val.dtype, cfg.serdes)
                    stats.cross_pod_beats += cfg.serdes.lanes
            flit_w = cfg.flit_data_width // 8
            buf_bytes = max(
                (sum(cfg.flits_for(v.nbytes) * flit_w for v, _, _ in msgs)
                 for msgs in per_pair.values()), default=0)
            if buf_bytes:
                msgs_arr = np.zeros((n, n, buf_bytes), np.uint8)
                for (s, d), msgs in per_pair.items():
                    off = 0
                    for v, _, _ in msgs:
                        raw = v.tobytes()
                        msgs_arr[s, d, off:off + len(raw)] = np.frombuffer(raw, np.uint8)
                        off += cfg.flits_for(v.nbytes) * flit_w  # flit padding
                delivered, sstats = simulate_schedule(topo, msgs_arr)
                stats.rounds += sstats.rounds
                stats.link_bytes += sstats.link_bytes
                for (s, d), msgs in per_pair.items():
                    off = 0
                    for v, dpe, dport in msgs:
                        raw = delivered[d, s, off:off + v.nbytes].tobytes()
                        mailbox[(dpe, dport)] = np.frombuffer(raw, v.dtype).reshape(v.shape).copy()
                        off += cfg.flits_for(v.nbytes) * flit_w
        outs = {f"{pe}.{port.name}": mailbox[(pe, port.name)] for pe, port in g.graph_outputs()}
        return outs, stats

    def run_iterative(self, inputs: Mapping[str, Any], feedback, n_iters: int,
                      mode: str = "sim") -> tuple[dict[str, Any], NoCStats]:
        state = dict(inputs)
        total = NoCStats()
        outs: dict[str, Any] = {}
        for _ in range(n_iters):
            outs, st = self.run(state, mode=mode)
            for f in dataclasses.fields(NoCStats):
                setattr(total, f.name, getattr(total, f.name) + getattr(st, f.name))
            for src, dst in feedback:
                state[dst] = outs[src]
        return outs, total
