"""NoC executor: run a TaskGraph over a Topology, optionally cut across pods.

This is the integration point of the framework (paper Fig. 1): PEs from
phase-1 (`core.graph`) are placed on a CONNECT-style topology
(`core.topology`), messages move via the topology's routing schedule
(`core.routing`), and cut links go through quasi-SERDES endpoints
(`core.serdes` via `core.partition`).

Execution modes — the three contracts
-------------------------------------
* ``direct``     — `TaskGraph.run`; the pure-software oracle (the paper's
  "multithreaded message passing software version").  No NoC, no stats.
* ``sim``        — the compiled **flit-program engine**: fires PEs
  wave-by-wave and physically moves every message round-by-round through the
  topology schedule with one vectorized numpy scatter/gather per wave.
  Produces the NoCStats used by the Table-IV/V-style benchmarks, and — by
  construction — bit-identical outputs to ``direct`` (tested).
* ``spmd``       — the **device-mesh execution** of the same compiled flit
  program: each wave's (n, n, buf_bytes) message cube is sharded over a
  device mesh (one NoC node per device, `partition.mesh_for_topology`) and
  moved by the topology's compiled ppermute-round schedule
  (`routing.compile_routes` / `run_route_program`) inside ``shard_map`` —
  one ``lax.ppermute`` per hop, multi-hop topologies decomposed into per-hop
  rounds, fat-tree as one fused ``lax.all_to_all``.  Outputs and NoCStats are
  bit-identical to ``sim`` (differential-tested): rounds/link_bytes come from
  `routing.route_program_stats`, which counts exactly what the round-by-round
  simulator counts.  Requires ``n_nodes`` devices (fake CPU devices via
  ``XLA_FLAGS=--xla_force_host_platform_device_count`` work).
* ``sim_python`` — the original per-message reference loop (dict framing +
  ``tobytes``/``frombuffer`` per message).  Kept as the behavioral baseline
  the engine is benchmarked and property-tested against.
* ``buffered``   — the **contention-aware wormhole transport** (`core.switch`):
  each wave's message cube moves flit-by-flit through per-port input FIFOs
  (``NoCConfig.switch_buffer_depth``) with X-Y dimension-ordered routing,
  round-robin output arbitration, credit backpressure, and dateline virtual
  channels (``switch_vcs``).  Bit-identical to ``sim``: outputs, ``waves``,
  ``payload_bytes``, ``flits``, and the ``cross_pod_*`` counters.
  Mode-specific: ``rounds`` counts switch *cycles* (contention included, so
  ≥ the contention-free schedule rounds), ``link_bytes`` counts flit-hops ×
  flit wire bytes under dimension-ordered routes, and the ``switch_*``
  counters (stalls, arbitration losses, peak queue/link occupancy) are
  populated.  With ``plan=`` it routes uncut but rolls the analytic bridge
  counters, like ``sim_python``.

The contract between the modes: ``direct`` defines values, ``sim`` defines
values + flit/round accounting, ``spmd`` must reproduce both bit-for-bit while
actually moving bytes between devices, and ``buffered`` must reproduce the
values and static counters while exposing the congestion the lock-step modes
cannot express.

Partitioned execution (``plan=``) — the inter-chip contract
-----------------------------------------------------------
Passing a `partition.PartitionPlan` turns on the paper's last automated step:
the compiled route program is split at the pod cut into per-pod programs
joined by explicit bridge endpoints (`core.interchip`).  Every pod-crossing
hop funnels its traffic through a quasi-SERDES serial link that
time-multiplexes the wide on-chip flits onto ``lanes`` narrow beats, with a
per-bridge FIFO (``NoCConfig.bridge_fifo_depth``) and bandwidth model.  The
cut is *semantically transparent* ("seamless" per the paper): outputs and all
pre-existing NoCStats fields — waves, rounds, link/payload/flit bytes, the
static cross-pod counters — are bit-identical to the unpartitioned execution
in every mode.  Only the new ``bridge_*`` counters (beats, serialized wire
bytes, stall rounds, peak FIFO occupancy — `interchip.BridgeStats`) record
what the serial links did:

* ``sim``   — `interchip.simulate_bridged_program` physically serializes
  every crossing buffer into wire words and back, round by round;
* ``spmd``  — `interchip.run_bridged_program` over
  `partition.mesh_for_partition` (a 2D ``(pod, node)`` device mesh when the
  plan's pods are equal contiguous blocks): intra-pod hops stay single
  ``lax.ppermute`` rounds, cut hops run serdes encode → ``lanes`` serialized
  beat ppermutes → decode; bridge counters come from the analytic
  `interchip.bridge_program_stats`, which matches the simulator exactly;
* ``sim_python`` — the seed loop routes unbridged but rolls in the same
  analytic bridge counters, staying field-for-field comparable.

The same compiled infrastructure also carries the LM-scale workload:
`models.moe` with ``impl="noc"`` routes expert-parallel token packets through
``routing.compile_routes`` / ``run_route_program`` (linearized over the
``model`` mesh axis — one ``lax.ppermute`` per hop, all four topologies), with
``routing.route_program_stats`` supplying exact flit/round/link-byte counters
per layer invocation (`models.moe.MoEDispatchStats`) and
``NoCConfig.flit_buffer_depth`` acting as the token-capacity knob — the
paper's "Data Distributor → routers → Data Collector" wrapper applied to a
mixture-of-experts layer.

The flit-program compile step
-----------------------------
Because the graph is *static* dataflow (every channel's shape/dtype is a
declared contract), the entire framing of a wave is known at executor
construction time.  ``NoCExecutor.__init__`` therefore compiles, per wave, a
:class:`_WaveProgram`:

* the flit-padded byte offset of every message inside its (src, dst) node
  buffer (CONNECT flit framing, ``flit_data_width`` granularity);
* flat ``pack_idx``/``gather_idx`` index vectors that scatter the wave's
  concatenated payload bytes into the ``(n, n, buf_bytes)`` message cube and
  gather them back out of the delivered ``(n_dst, n_src, buf_bytes)`` cube;
* the wave's *static* NoCStats increments (payload bytes, flit count,
  cross-pod message/wire-byte/beat counts) — these depend only on contracts
  and placement, never on values.

``run`` then does one ``reshape(-1)[pack_idx] = payload`` scatter, one
``simulate_schedule`` call, and one ``reshape(-1)[gather_idx]`` gather per
wave instead of per-message Python loops; ``run_iterative`` reuses the
compiled program across all iterations, and ``run_batch`` moves B independent
input sets through the topology in a single ``(B, n, n, bytes)`` simulation.
PE bodies are jit-cached per PE (with a transparent eager fallback), so the
firing side of the wave is compiled once as well.

Flit accounting mirrors CONNECT's link model (default flit_data_width=16,
the paper's BMVM NoC config) and powers the Tables I–III "with/without
wrapper" overhead analogs: on TPU the wrapper cost is not LUTs/registers but
the padding + framing + buffer bytes the NoC abstraction adds around the raw
message payload.

Static verification (``verify=``) — the analysis contract
---------------------------------------------------------
Because everything above is compiled *before* any value moves, it can also be
*proven* before any value moves.  ``NoCExecutor(verify="strict")`` (the
default) runs `repro.analysis.verify_executor` over the artifacts it just
compiled:

* deadlock freedom of ``(topo, cfg.switch_vcs)`` via the Dally–Seitz channel
  dependency graph of the switch's actual routing function (NOC001/NOC002);
* exactly-once delivery/conservation of the compiled route program, the
  bridged pod projections, and every wave's pack/gather layout
  (NOC003/NOC004);
* placement / pod-cut / config validity (NOC007/NOC008/NOC009/NOC012) and
  framing-mismatch warnings (NOC010);
* capacity bounds: exact flit/link-byte totals plus sound peak-occupancy
  upper bounds on the `NoCStats` high-water marks (NOC005/NOC013 warnings).

``"strict"`` raises `repro.analysis.VerificationError` on any error-severity
finding, ``"warn"`` reports via ``warnings.warn``, ``"off"`` skips; the full
diagnostic list is kept on ``self.verification`` either way.  The property
suite holds the verifier to its word: artifacts it passes must simulate to
completion with stats inside the predicted bounds (see
``tests/test_analysis.py`` and the error-code reference in
`repro.analysis`).

Telemetry (``trace=``) — the observability contract
---------------------------------------------------
``NoCExecutor(trace=repro.telemetry.Tracer())`` (or ``trace=True``) threads
an event tracer through every execution mode: per-wave
scatter/route/gather/wave spans, one ``msg`` event per compiled message
slot (with the cross-pod wire cost when the message crosses the cut),
per-round ``round``/``link`` events derived from the compiled route program
(exact — `routing.route_program_stats` counts what the simulators count),
per-cycle ``cycle``/``queue`` events from the wormhole switch in
``mode="buffered"``, and ``bridge_*`` events from the bridge FIFO machine
shared by the bridged simulator and the analytic stats.  The full event
schema lives in `repro.telemetry.tracer`; timestamps are logical NoC time
(scatter 1 tick, route = rounds/cycles + bridge stalls, gather 1 tick).

The contract, differential-tested across the topology × app × mode grid:
``repro.telemetry.trace_stats(tracer)`` reproduces the run's `NoCStats`
**bit-exactly** — the trace is a proof-carrying account of the run, not a
best-effort log.  With ``trace=None`` (the default) no event object is
allocated anywhere (every hook is one ``is not None`` check;
property-tested), so tracing costs nothing when off.  On top of the raw
events, `repro.telemetry.profile.profile_trace` rebuilds per-packet /
per-message latency records (inject→eject, decomposed exactly into
serialization + hop + queueing + bridge-stall) and attributes every tick
above the analytic bounds to a named resource — see ``docs/observability.md``
for the full telemetry contract, the ``noc.latency.*`` metrics schema and
how to read the bottleneck report.  Exporters:
`repro.telemetry.chrome_trace` (Perfetto/Chrome timeline — one track per
router/link/bridge, counter tracks for queue depth and link load),
`repro.telemetry.heatmap` (text/CSV link utilization, also via
``python -m repro.launch.report --trace``), and ``python -m
repro.telemetry`` runs any case-study app traced.  Independent of tracing,
every engine publishes its `NoCStats` into the process-wide metrics
registry when one is enabled (`repro.telemetry.metrics.enable_metrics`) —
flows as counters, high-water marks as max-gauges, labeled by
``mode``/``topology``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional

import numpy as np

import jax

from . import serdes as qserdes
from ..telemetry.metrics import get_registry
from ..telemetry.tracer import Tracer
from .graph import GraphError, TaskGraph
from .partition import PartitionPlan
from .routing import simulate_schedule
from .topology import Topology


@dataclasses.dataclass
class NoCStats:
    waves: int = 0
    rounds: int = 0
    link_bytes: int = 0
    payload_bytes: int = 0
    flits: int = 0
    cross_pod_msgs: int = 0
    cross_pod_wire_bytes: int = 0
    cross_pod_beats: int = 0
    # bridge counters (core.interchip) — nonzero only under partitioned
    # execution (plan=); everything above is identical with or without a cut
    bridge_beats: int = 0          # serial-lane cycles on the cut links
    bridge_wire_bytes: int = 0     # serialized bytes incl. word/lane padding
    bridge_stall_rounds: int = 0   # back-pressure + drain rounds at bridges
    bridge_peak_fifo: int = 0      # max bridge FIFO occupancy (wire words)
    # buffered-switch counters (core.switch) — nonzero only in mode="buffered"
    switch_cycles: int = 0         # wormhole cycles across all waves
    switch_stall_cycles: int = 0   # head flits blocked on credit/VC allocation
    switch_arb_losses: int = 0     # eligible flits that lost an arbitration
    switch_max_queue: int = 0      # peak input-FIFO occupancy, flits
    switch_peak_link_flits: int = 0  # peak flits on links in one cycle

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def add(self, other: "NoCStats") -> "NoCStats":
        for f in dataclasses.fields(NoCStats):
            a, b = getattr(self, f.name), getattr(other, f.name)
            # peak occupancies are high-water marks, not flows — merge by max
            setattr(self, f.name,
                    max(a, b) if f.name in _MAX_MERGE_FIELDS else a + b)
        return self

    def bridge_counters(self) -> dict:
        return {k: v for k, v in self.as_dict().items()
                if k.startswith("bridge_")}

    def _roll_bridge(self, b) -> None:
        """Fold one wave's BridgeStats in (peak merged by max)."""
        self.bridge_beats += b.beats
        self.bridge_wire_bytes += b.wire_bytes
        self.bridge_stall_rounds += b.stall_rounds
        self.bridge_peak_fifo = max(self.bridge_peak_fifo, b.peak_fifo)

    def _roll_switch(self, sw) -> None:
        """Fold one wave's SwitchStats in (peaks merged by max)."""
        self.switch_cycles += sw.cycles
        self.switch_stall_cycles += sw.stall_cycles
        self.switch_arb_losses += sw.arb_losses
        self.switch_max_queue = max(self.switch_max_queue, sw.max_queue)
        self.switch_peak_link_flits = max(self.switch_peak_link_flits,
                                          sw.peak_link_flits)


# high-water-mark fields: NoCStats.add merges these by max, not sum
_MAX_MERGE_FIELDS = frozenset(
    {"bridge_peak_fifo", "switch_max_queue", "switch_peak_link_flits"})


@dataclasses.dataclass(frozen=True)
class NoCConfig:
    """CONNECT "Network and Router Options" analog (paper §VI-B).

    ``flit_buffer_depth`` is the capacity knob for MoE dispatch over the NoC
    (`models.moe`): each (source rank, expert) dispatch FIFO holds that many
    token slots, and the MoE's effective ``capacity_factor`` is *derived* from
    it (see `models.moe.dispatch_capacity`) instead of being configured
    independently — one knob, the paper's buffer-depth sweep."""

    flit_data_width: int = 16          # bits
    flit_buffer_depth: int = 8         # per-(src, expert) FIFO depth, in slots
    bridge_fifo_depth: int = 64        # inter-chip bridge FIFO, in wire words
    switch_buffer_depth: int = 4       # buffered mode: input FIFO depth, flits
    switch_vcs: int = 2                # buffered mode: VCs per input port
    serdes: qserdes.QuasiSerdesConfig = dataclasses.field(
        default_factory=qserdes.QuasiSerdesConfig)

    def __post_init__(self):
        # eager NOC012 validation: a bad width/depth must fail at config
        # construction, not deep inside a simulation
        for f in ("flit_data_width", "flit_buffer_depth", "bridge_fifo_depth",
                  "switch_buffer_depth", "switch_vcs"):
            v = getattr(self, f)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"NOC012: NoCConfig.{f}={v!r} must be a "
                                 f"positive integer")

    @property
    def flit_wire_bytes(self) -> int:
        """On-wire/storage bytes of ONE flit: ceil(width/8).  A 12-bit flit
        occupies 2 bytes of FIFO storage — truncating division silently
        under-counted every non-byte-multiple width."""
        return -(-self.flit_data_width // 8)

    def flits_for(self, nbytes: int) -> int:
        # payload capacity of a flit is the *whole* bytes it can carry
        # (floor), never 0 for sub-byte widths
        per = max(1, self.flit_data_width // 8)
        return -(-nbytes // per)

    def flit_framed_bytes(self, nbytes: int) -> int:
        """THE flit-framing rule: payload bytes → on-link/FIFO bytes (whole
        flits × ceiling flit storage).  Every framing call site — wave
        compilation, the seed loop, wrapper-overhead accounting — goes
        through here so the ceiling-division arithmetic lives in one place."""
        return self.flits_for(nbytes) * self.flit_wire_bytes


def wrapper_overhead(graph: TaskGraph, cfg: Optional[NoCConfig] = None) -> list[dict]:
    """Tables I–III analog: per-PE cost without vs with the NoC wrapper.

    'wo_wrapper_bytes'  — the PE's raw argument/result bytes (the bare module);
    'fifo_bytes'        — Data Collector/Distributor FIFO storage;
    'flit_bytes'        — framed on-link size incl. padding to flit width;
    'overhead'          — (with - without) / without, the Table-I ratio.
    """
    cfg = cfg or NoCConfig()
    rows = []
    for pe in graph.pes.values():
        in_b = sum(p.nbytes for p in pe.inputs)
        out_b = sum(p.nbytes for p in pe.outputs)
        raw = in_b + out_b
        fifo = cfg.flit_buffer_depth * cfg.flit_wire_bytes * (len(pe.inputs) + len(pe.outputs))
        flit_b = sum(cfg.flit_framed_bytes(p.nbytes)
                     for p in list(pe.inputs) + list(pe.outputs))
        rows.append(dict(pe=pe.name, wo_wrapper_bytes=raw, fifo_bytes=fifo,
                         flit_bytes=flit_b, with_wrapper_bytes=flit_b + fifo,
                         overhead=round((flit_b + fifo - raw) / max(raw, 1), 3)))
    return rows


# ---------------------------------------------------------------------------
# compiled flit program
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _MsgSlot:
    """One channel message inside a wave's compiled layout."""

    src_pe: str
    src_port: str
    dst_pe: str
    dst_port: str
    shape: tuple[int, ...]
    dtype: np.dtype
    nbytes: int
    a: int                 # [a:b) segment in the wave's payload byte vector
    b: int


@dataclasses.dataclass(frozen=True)
class _WaveProgram:
    """Static framing layout of one wave (compiled at executor construction)."""

    slots: tuple[_MsgSlot, ...]
    payload_nbytes: int    # Σ raw message bytes (the payload vector length)
    buf_bytes: int         # per-(src,dst) buffer size incl. flit padding
    pack_idx: np.ndarray   # flat indices into (n, n, buf_bytes) per payload byte
    gather_idx: np.ndarray # flat indices into delivered (n_dst, n_src, buf_bytes)
    static: NoCStats       # value-independent stats increment for this wave
    pairs: tuple[tuple[int, int, int], ...]  # occupied (src, dst, framed_bytes)


class NoCExecutor:
    def __init__(self, graph: TaskGraph, topo: Topology,
                 placement: Optional[Mapping[str, int]] = None,
                 plan: Optional[PartitionPlan] = None,
                 cfg: Optional[NoCConfig] = None,
                 verify: str = "strict",
                 trace: Optional[Any] = None):
        from .partition import place_round_robin

        if verify not in ("strict", "warn", "off"):
            raise ValueError(f"verify must be 'strict', 'warn', or 'off', "
                             f"got {verify!r}")
        # trace: None (off, zero overhead) | a telemetry Tracer | True for a
        # default-capacity one.  Kept on self.tracer; shared across runs so
        # run_iterative/run_batch build one continuous timeline.
        self.tracer = Tracer() if trace is True else trace
        self.graph = graph
        self.topo = topo
        self.placement = dict(placement or (plan.placement if plan else place_round_robin(graph, topo)))
        self.plan = plan
        self.cfg = cfg or NoCConfig()
        graph.validate()
        self._order = graph.firing_order()
        # group PEs into waves by dataflow depth
        depth: dict[str, int] = {}
        preds: dict[str, set[str]] = {n: set() for n in graph.pes}
        for c in graph.channels:
            if c.src_pe != c.dst_pe:
                preds[c.dst_pe].add(c.src_pe)
        for n in self._order:
            depth[n] = 1 + max((depth[p] for p in preds[n]), default=-1)
        self.waves: list[list[str]] = []
        for n in self._order:
            while len(self.waves) <= depth[n]:
                self.waves.append([])
            self.waves[depth[n]].append(n)
        self._chan_by_src: dict[str, list] = {n: [] for n in graph.pes}
        for c in graph.channels:
            self._chan_by_src[c.src_pe].append(c)
        self.programs: list[_WaveProgram] = [self._compile_wave(w) for w in self.waves]
        self._hop_cache: dict[tuple[int, int], int] = {}   # (src, dst) -> hops
        # jit caches for PE firing (sim/batch modes), keyed by id(pe.fn);
        # fall back to eager per distinct body
        self._jit_fns: dict[int, Any] = {}
        self._jit_ok: dict[int, bool] = {}
        self._vmap_fns: dict[int, Any] = {}
        self._vmap_ok: dict[int, bool] = {}
        # spmd lowering (mode="spmd") is built lazily on first use: it needs
        # n_nodes real/fake devices, which sim-only runs must not require.
        # The bridged program (plan=) is likewise compiled on first partitioned
        # run — it needs no devices, only the route program + the cut.
        self._route_prog = None
        self._bridge_prog = None
        self._spmd_mesh = None
        self._spmd_fn = None
        # static verification of everything just compiled (repro.analysis):
        # deadlock proof for (topo, switch_vcs), delivery proofs for the wave
        # layouts and route program, placement/cut linting, capacity bounds.
        self.verification = []
        if verify != "off":
            from ..analysis.diagnostics import (VerificationError, errors,
                                                format_diagnostics)
            from ..analysis.lint import verify_executor

            self.verification = verify_executor(self)
            if errors(self.verification) and verify == "strict":
                raise VerificationError(self.verification)
            if self.verification and verify == "warn":
                import warnings

                warnings.warn(format_diagnostics(self.verification),
                              stacklevel=2)

    def _ensure_bridge(self):
        """Compile the partitioned (bridged) program once per executor."""
        if self.plan is None:
            return None
        if self._bridge_prog is None:
            from .interchip import BridgeConfig, compile_bridges
            from .routing import compile_routes

            if self._route_prog is None:
                self._route_prog = compile_routes(self.topo)
            self._bridge_prog = compile_bridges(
                self._route_prog, self.plan,
                BridgeConfig(serdes=self.plan.serdes_cfg,
                             fifo_depth=self.cfg.bridge_fifo_depth))
        return self._bridge_prog

    # -- compile -------------------------------------------------------------
    def _compile_wave(self, wave: list[str]) -> _WaveProgram:
        g, cfg = self.graph, self.cfg
        n = self.topo.n_nodes
        pod_of = self.plan.pod_of_node if self.plan is not None else None
        slots: list[_MsgSlot] = []
        pair_off: dict[tuple[int, int], int] = {}
        static = NoCStats()
        seg = 0
        placed: list[tuple[int, int, int]] = []   # (src_node, dst_node, pair_offset)
        for name in wave:
            for c in self._chan_by_src[name]:
                port = g.pes[c.src_pe].out_port(c.src_port)
                nbytes = port.nbytes
                s, d = self.placement[c.src_pe], self.placement[c.dst_pe]
                off = pair_off.get((s, d), 0)
                pair_off[(s, d)] = off + cfg.flit_framed_bytes(nbytes)  # flit padding
                slots.append(_MsgSlot(c.src_pe, c.src_port, c.dst_pe, c.dst_port,
                                      tuple(port.shape), np.dtype(port.dtype),
                                      nbytes, seg, seg + nbytes))
                placed.append((s, d, off))
                seg += nbytes
                static.payload_bytes += nbytes
                static.flits += cfg.flits_for(nbytes)
                if pod_of is not None and pod_of[s] != pod_of[d]:
                    static.cross_pod_msgs += 1
                    static.cross_pod_wire_bytes += qserdes.link_bytes_on_wire(
                        tuple(port.shape), port.dtype, cfg.serdes)
                    static.cross_pod_beats += cfg.serdes.lanes
        buf_bytes = max(pair_off.values(), default=0)
        pack, gather = [], []
        for slot, (s, d, off) in zip(slots, placed):
            span = np.arange(off, off + slot.nbytes, dtype=np.int64)
            pack.append((s * n + d) * buf_bytes + span)
            gather.append((d * n + s) * buf_bytes + span)   # delivered is (dst, src)
        def cat(xs):
            return np.concatenate(xs) if xs else np.zeros(0, np.int64)
        return _WaveProgram(tuple(slots), seg, buf_bytes, cat(pack), cat(gather),
                            static,
                            tuple((s, d, nb) for (s, d), nb
                                  in sorted(pair_off.items())))

    # -- firing --------------------------------------------------------------
    # jit/vmap caches are keyed by the fn object, not the PE name: graphs that
    # register one body for many PEs (e.g. the particle-filter group PEs)
    # compile each distinct body once.  PE objects keep their fns alive for the
    # executor's lifetime, so id() keys are stable.

    def _fire(self, name: str, kwargs: dict[str, Any]) -> Mapping[str, Any]:
        """Call a PE body through the jit cache; eager fallback on failure."""
        pe = self.graph.pes[name]
        key = id(pe.fn)
        if self._jit_ok.get(key, True):
            fn = self._jit_fns.get(key)
            if fn is None:
                fn = self._jit_fns[key] = jax.jit(pe.fn)
            try:
                return fn(**kwargs)
            except Exception:
                self._jit_ok[key] = False
        return pe.fn(**kwargs)

    def _fire_batch(self, name: str, kwargs: dict[str, Any], B: int) -> Mapping[str, Any]:
        """Fire one PE on B stacked input sets; vmap with per-item fallback."""
        pe = self.graph.pes[name]
        key = id(pe.fn)
        if self._vmap_ok.get(key, True):
            fn = self._vmap_fns.get(key)
            if fn is None:
                fn = self._vmap_fns[key] = jax.jit(jax.vmap(pe.fn))
            try:
                return fn(**kwargs)
            except Exception:
                self._vmap_ok[key] = False
        items = [pe.fn(**{k: v[b] for k, v in kwargs.items()}) for b in range(B)]
        return {p.name: np.stack([np.asarray(it[p.name]) for it in items])
                for p in pe.outputs}

    # -- spmd lowering -------------------------------------------------------
    def _ensure_spmd(self) -> None:
        """Compile the topology schedule to a ppermute-round program and jit
        the shard_map transport over the NoC device mesh (once per executor).

        With a partition plan, the transport is the *bridged* program over
        `partition.mesh_for_partition` — a ``(pod, node)`` mesh when the
        plan's pods are equal contiguous blocks — where intra-pod hops stay
        ppermute rounds and cut hops run through quasi-SERDES endpoints
        (`interchip.run_bridged_program`)."""
        if self._spmd_fn is not None:
            return
        from jax.sharding import PartitionSpec as P

        from ..compat import shard_map
        from .partition import mesh_for_partition, mesh_for_topology
        from .routing import compile_routes, run_route_program

        if self._route_prog is None:
            self._route_prog = compile_routes(self.topo)
        prog = self._route_prog
        bprog = self._ensure_bridge()
        if bprog is not None:
            from .interchip import run_bridged_program

            mesh = self._spmd_mesh = mesh_for_partition(self.topo, self.plan)
            names = mesh.axis_names
            n_lead = len(names)

            def device_fn(local):
                x = local.reshape(local.shape[n_lead:])
                return run_bridged_program(x, bprog, names).reshape(local.shape)
        else:
            mesh = self._spmd_mesh = mesh_for_topology(self.topo)
            names = tuple(a for a, _ in prog.axes)
            n_lead = len(names)

            def device_fn(local):
                # local view: (1,)*n_lead + (n_dst, *payload) → route → same
                x = local.reshape(local.shape[n_lead:])
                return run_route_program(x, prog).reshape(local.shape)

        sm = shard_map(device_fn, mesh=mesh, in_specs=P(*names),
                       out_specs=P(*names), check_vma=False)
        self._spmd_fn = jax.jit(sm)

    def _route_spmd(self, msgs_arr: np.ndarray, B: Optional[int]):
        """Move one wave's message cube through the device mesh.

        msgs_arr: (n, n, buf) or (B, n, n, buf).  Same (delivered, stats)
        contract as :func:`simulate_schedule` — the batch rides along as
        payload bytes, so rounds are physical while link_bytes scale with B.
        Returns ``(delivered, ScheduleStats, BridgeStats | None)``; the
        bridge stats are analytic (`interchip.bridge_program_stats`), which
        the simulator matches exactly."""
        from .routing import route_program_stats

        self._ensure_spmd()
        prog = self._route_prog
        n = self.topo.n_nodes
        sizes = tuple(self._spmd_mesh.devices.shape)
        if B is None:
            payload = msgs_arr.shape[2:]
            cube = msgs_arr.reshape(sizes + (n,) + payload)
        else:
            payload = (B,) + msgs_arr.shape[3:]
            cube = np.moveaxis(msgs_arr, 0, 2).reshape(sizes + (n,) + payload)
        out = np.asarray(self._spmd_fn(cube)).reshape((n, n) + payload)
        delivered = out if B is None else np.moveaxis(out, 2, 0)
        bstats = None
        if self._bridge_prog is not None:
            from .interchip import bridge_program_stats

            bstats = bridge_program_stats(self._bridge_prog, msgs_arr.nbytes,
                                          tracer=self.tracer)
        return (np.ascontiguousarray(delivered),
                route_program_stats(prog, msgs_arr.nbytes), bstats)

    # -- telemetry -----------------------------------------------------------
    def _hops(self, s: int, d: int) -> int:
        """Topology hop distance ``s -> d`` under dimension-ordered routing —
        the per-message ``hops`` attribution the latency profiler charges as
        the in-flight component (cached; identical for every transport)."""
        h = self._hop_cache.get((s, d))
        if h is None:
            from .switch import dor_route

            h = len(dor_route(self.topo, s, d, max(2, self.cfg.switch_vcs))[0]) - 1
            self._hop_cache[(s, d)] = h
        return h

    def _trace_msgs(self, tr, prog: _WaveProgram, scale: int, t0: int) -> None:
        """One ``msg`` event per compiled slot — the event-level mirror of
        ``prog.static`` (payload/flit/cross-pod counters, scaled by the batch
        via the ``n`` arg), which is what makes trace aggregation exact."""
        cfg = self.cfg
        pod_of = self.plan.pod_of_node if self.plan is not None else None
        for slot in prog.slots:
            s, d = self.placement[slot.src_pe], self.placement[slot.dst_pe]
            args = dict(src=s, dst=d, bytes=slot.nbytes,
                        flits=cfg.flits_for(slot.nbytes), n=scale,
                        hops=self._hops(s, d))
            if pod_of is not None and pod_of[s] != pod_of[d]:
                args["wire_bytes"] = qserdes.link_bytes_on_wire(
                    slot.shape, slot.dtype, cfg.serdes)
                args["beats"] = cfg.serdes.lanes
            tr.instant("msg", f"node {s}", ts=t0, **args)

    def _trace_rounds(self, tr, t0: int, cube_nbytes: int) -> None:
        """Per-round ``round`` instants + per-link ``link`` load counters for
        the schedule transports, derived from the compiled route program —
        `interchip._walk_rounds` traversals move ``cube_nbytes // den`` each,
        summing to exactly `routing.route_program_stats` (== what the
        simulators count), so the events are exact, not estimated."""
        from .interchip import _walk_rounds

        if self._route_prog is None:
            from .routing import compile_routes

            self._route_prog = compile_routes(self.topo)
        for r, (den, pairs) in enumerate(_walk_rounds(self._route_prog)):
            per = cube_nbytes // den
            agg: dict[tuple[int, int], int] = {}
            for p in pairs:
                agg[p] = agg.get(p, 0) + per
            tr.instant("round", "noc", ts=t0 + r,
                       bytes=per * len(pairs), links=len(agg))
            for (s, d), b in agg.items():
                tr.counter("link", f"link {s}->{d}", b, ts=t0 + r)

    # -- packing -------------------------------------------------------------
    @staticmethod
    def _payload_segment(val: Any, slot: _MsgSlot, lead: tuple[int, ...] = ()) -> np.ndarray:
        v = np.asarray(val)
        if v.shape != lead + slot.shape or v.dtype != slot.dtype:
            raise GraphError(
                f"message {slot.src_pe}.{slot.src_port} -> {slot.dst_pe}.{slot.dst_port}: "
                f"value {v.shape}/{v.dtype} violates contract {lead + slot.shape}/{slot.dtype}")
        flat = np.ascontiguousarray(v).reshape(*lead, -1) if lead else \
            np.ascontiguousarray(v).reshape(-1)
        return flat.view(np.uint8).reshape(*lead, -1) if lead else flat.view(np.uint8)

    # ------------------------------------------------------------------
    def run(self, inputs: Mapping[str, Any], mode: str = "sim") -> tuple[dict[str, Any], NoCStats]:
        if mode == "direct":
            return self.graph.run(inputs), NoCStats()
        if mode == "sim_python":
            return self._run_sim_python(inputs)
        if mode not in ("sim", "spmd", "buffered"):
            raise GraphError(f"unknown mode {mode!r}; use "
                             f"'direct'|'sim'|'spmd'|'buffered'|'sim_python'")
        mailbox: dict[tuple[str, str], Any] = {}
        for k, v in inputs.items():
            pe, port = k.split(".")
            mailbox[(pe, port)] = np.asarray(v)
        return self._run_compiled(mailbox, B=None, transport=mode)

    def run_batch(self, inputs: Mapping[str, Any],
                  mode: str = "sim") -> tuple[dict[str, Any], NoCStats]:
        """Run B independent input sets at once; every input carries a leading
        batch axis ``(B, *port.shape)`` and so does every output.

        ``sim`` fires each PE once on the stacked batch (vmap, with a per-item
        eager fallback) and moves all B message sets through the topology in a
        single ``(B, n, n, bytes)`` :func:`simulate_schedule` call.  Stats:
        waves/rounds are physical (counted once — the batch shares the
        schedule), while payload/flit/link/cross-pod byte counters scale with
        B (each input set's messages really occupy the links)."""
        if not inputs:
            raise GraphError("run_batch needs at least one input")
        B = int(np.asarray(next(iter(inputs.values()))).shape[0])
        if mode == "direct":
            items = [self.graph.run({k: np.asarray(v)[b] for k, v in inputs.items()})
                     for b in range(B)]
            outs = {k: np.stack([np.asarray(it[k]) for it in items]) for k in items[0]}
            return outs, NoCStats()
        if mode not in ("sim", "spmd", "buffered"):
            raise GraphError(f"unknown mode {mode!r}; use "
                             f"'direct'|'sim'|'spmd'|'buffered'")
        mailbox: dict[tuple[str, str], Any] = {}
        for k, v in inputs.items():
            pe, port = k.split(".")
            arr = np.asarray(v)
            if arr.shape[0] != B:
                raise GraphError(f"input {k} batch axis {arr.shape[0]} != {B}")
            mailbox[(pe, port)] = arr
        return self._run_compiled(mailbox, B=B, transport=mode)

    def _switch_cfg(self):
        """NoCConfig knobs → the buffered transport's SwitchConfig."""
        from .switch import SwitchConfig

        return SwitchConfig(buffer_depth=self.cfg.switch_buffer_depth,
                            n_vcs=self.cfg.switch_vcs,
                            flit_bytes=self.cfg.flit_wire_bytes)

    def _run_compiled(self, mailbox: dict[tuple[str, str], Any],
                      B: Optional[int],
                      transport: str = "sim") -> tuple[dict[str, Any], NoCStats]:
        """Execute the compiled flit program; ``B=None`` single-set, else a
        leading batch axis rides through every pack/route/unpack step.

        ``transport`` swaps how each wave's message cube moves: ``"sim"`` is
        the round-by-round numpy schedule simulator, ``"spmd"`` the compiled
        ppermute program on the device mesh, ``"buffered"`` the cycle-accurate
        wormhole switch (`core.switch`).  Everything else — firing, framing,
        stats accumulation — is shared, which is what makes the modes
        bit-identical on values by construction."""
        g, topo = self.graph, self.topo
        n = topo.n_nodes
        lead = () if B is None else (B,)
        scale = 1 if B is None else B
        stats = NoCStats()
        if transport == "spmd":
            self._ensure_spmd()     # fail fast if the mesh can't be built
        tr = self.tracer
        if tr is not None:
            tr.instant("run", "noc", mode=transport,
                       topology=type(topo).__name__, n_nodes=n, batch=scale)
        for iw, (wave, prog) in enumerate(zip(self.waves, self.programs)):
            stats.waves += 1
            for name in wave:
                pe = g.pes[name]
                kwargs = {p.name: mailbox[(name, p.name)] for p in pe.inputs}
                results = (self._fire(name, kwargs) if B is None
                           else self._fire_batch(name, kwargs, B))
                for p in pe.outputs:
                    mailbox[(name, p.name)] = np.asarray(results[p.name])
            if not prog.slots:
                if tr is not None:   # message-free wave: scatter+gather only
                    tr.span("wave", "noc", tr.clock, 2, wave=iw, msgs=0)
                    tr.clock += 2
                continue
            payload = np.empty(lead + (prog.payload_nbytes,), np.uint8)
            for slot in prog.slots:
                payload[..., slot.a:slot.b] = self._payload_segment(
                    mailbox[(slot.src_pe, slot.src_port)], slot, lead)
            msgs_arr = np.zeros(lead + (n * n * prog.buf_bytes,), np.uint8)
            msgs_arr[..., prog.pack_idx] = payload
            cube = msgs_arr.reshape(lead + (n, n, prog.buf_bytes))
            t0 = 0
            if tr is not None:
                t0 = tr.clock
                self._trace_msgs(tr, prog, scale, t0)
                tr.clock = t0 + 1   # transport events base at route start
            bstats = None
            if transport == "spmd":
                delivered, sstats, bstats = self._route_spmd(cube, B)
                rounds, link_bytes = sstats.rounds, sstats.link_bytes
            elif transport == "buffered":
                from .switch import simulate_wormhole_cube

                delivered, swst = simulate_wormhole_cube(
                    topo, cube, self._switch_cfg(), pairs=prog.pairs,
                    batched=B is not None, tracer=tr)
                # mode-specific accounting: rounds are switch cycles (with
                # contention), link_bytes are flit-hops on the wormhole routes
                rounds = swst.cycles
                link_bytes = swst.link_flits * self.cfg.flit_wire_bytes
                stats._roll_switch(swst)
                if self.plan is not None:
                    # uncut routing + analytic bridge counters, the
                    # sim_python precedent for non-bridged transports
                    from .interchip import bridge_program_stats

                    bstats = bridge_program_stats(self._ensure_bridge(),
                                                  cube.nbytes, tracer=tr)
            elif self.plan is not None:
                # partitioned execution: same schedule, but pod-crossing hops
                # physically serialize through the bridge endpoints
                from .interchip import simulate_bridged_program

                delivered, sstats, bstats = simulate_bridged_program(
                    self._ensure_bridge(), cube, batched=B is not None,
                    tracer=tr)
                rounds, link_bytes = sstats.rounds, sstats.link_bytes
            else:
                delivered, sstats = simulate_schedule(topo, cube,
                                                      batched=B is not None)
                rounds, link_bytes = sstats.rounds, sstats.link_bytes
            recv = delivered.reshape(lead + (-1,))[..., prog.gather_idx]
            for slot in prog.slots:
                seg = recv[..., slot.a:slot.b].copy()   # owns + aligns the bytes
                mailbox[(slot.dst_pe, slot.dst_port)] = (
                    seg.view(slot.dtype).reshape(lead + slot.shape))
            # prog.static only carries per-message counters (waves/rounds/
            # link_bytes stay zero there), so the whole struct scales by B
            for f in dataclasses.fields(NoCStats):
                setattr(stats, f.name,
                        getattr(stats, f.name) + scale * getattr(prog.static, f.name))
            stats.rounds += rounds
            stats.link_bytes += link_bytes
            if bstats is not None:
                stats._roll_bridge(bstats)
            if tr is not None:
                durR = rounds + (bstats.stall_rounds
                                 if bstats is not None else 0)
                if transport in ("sim", "spmd"):
                    # buffered emitted its own per-cycle events; the schedule
                    # transports get the compiled program's exact rounds
                    self._trace_rounds(tr, t0 + 1, cube.nbytes)
                tr.span("scatter", "engine", t0, 1, msgs=len(prog.slots),
                        bytes=scale * prog.payload_nbytes)
                tr.span("route", "engine", t0 + 1, max(durR, 1),
                        mode=transport)
                tr.span("gather", "engine", t0 + 1 + durR, 1)
                tr.span("wave", "noc", t0, durR + 2, wave=iw,
                        msgs=len(prog.slots))
                tr.clock = t0 + durR + 2
        outs = {f"{pe}.{port.name}": mailbox[(pe, port.name)] for pe, port in g.graph_outputs()}
        reg = get_registry()
        if reg is not None:
            reg.record_noc_stats(stats, mode=transport,
                                 topology=type(topo).__name__)
        return outs, stats

    # ------------------------------------------------------------------
    def _run_sim_python(self, inputs: Mapping[str, Any]) -> tuple[dict[str, Any], NoCStats]:
        """The seed per-message reference loop (framing re-derived every wave).

        Kept verbatim as the baseline the compiled engine is benchmarked and
        property-tested against."""
        g, topo, cfg = self.graph, self.topo, self.cfg
        stats = NoCStats()
        mailbox: dict[tuple[str, str], Any] = {}
        for k, v in inputs.items():
            pe, port = k.split(".")
            mailbox[(pe, port)] = np.asarray(v)

        pod_of = None
        if self.plan is not None:
            pod_of = self.plan.pod_of_node

        tr = self.tracer
        if tr is not None:
            tr.instant("run", "noc", mode="sim_python",
                       topology=type(topo).__name__, n_nodes=topo.n_nodes,
                       batch=1)
        for iw, wave in enumerate(self.waves):
            stats.waves += 1
            # fire
            outbox: list[tuple[Any, int, int, str, str]] = []  # (val, src_node, dst_node, dst_pe, dst_port)
            for name in wave:
                pe = g.pes[name]
                kwargs = {p.name: mailbox[(name, p.name)] for p in pe.inputs}
                results = pe.fn(**kwargs)
                for p in pe.outputs:
                    mailbox[(name, p.name)] = np.asarray(results[p.name])
                for c in self._chan_by_src[name]:
                    val = np.asarray(results[c.src_port])
                    outbox.append((val, self.placement[c.src_pe], self.placement[c.dst_pe],
                                   c.dst_pe, c.dst_port))
            if not outbox:
                if tr is not None:
                    tr.span("wave", "noc", tr.clock, 2, wave=iw, msgs=0)
                    tr.clock += 2
                continue
            # frame messages into per-(src,dst) flit buffers and route them
            n = topo.n_nodes
            t0 = tr.clock if tr is not None else 0
            per_pair: dict[tuple[int, int], list] = {}
            for val, s, d, dpe, dport in outbox:
                per_pair.setdefault((s, d), []).append((val, dpe, dport))
                stats.payload_bytes += val.nbytes
                stats.flits += cfg.flits_for(val.nbytes)
                margs = None
                if tr is not None:
                    margs = dict(src=s, dst=d, bytes=val.nbytes,
                                 flits=cfg.flits_for(val.nbytes), n=1,
                                 hops=self._hops(s, d))
                if pod_of is not None and pod_of[s] != pod_of[d]:
                    wb = qserdes.link_bytes_on_wire(val.shape, val.dtype,
                                                    cfg.serdes)
                    stats.cross_pod_msgs += 1
                    stats.cross_pod_wire_bytes += wb
                    stats.cross_pod_beats += cfg.serdes.lanes
                    if margs is not None:
                        margs["wire_bytes"] = wb
                        margs["beats"] = cfg.serdes.lanes
                if margs is not None:
                    tr.instant("msg", f"node {s}", ts=t0, **margs)
            buf_bytes = max(
                (sum(cfg.flit_framed_bytes(v.nbytes) for v, _, _ in msgs)
                 for msgs in per_pair.values()), default=0)
            durR = 0
            if buf_bytes:
                msgs_arr = np.zeros((n, n, buf_bytes), np.uint8)
                for (s, d), msgs in per_pair.items():
                    off = 0
                    for v, _, _ in msgs:
                        raw = v.tobytes()
                        msgs_arr[s, d, off:off + len(raw)] = np.frombuffer(raw, np.uint8)
                        off += cfg.flit_framed_bytes(v.nbytes)  # flit padding
                if tr is not None:
                    tr.clock = t0 + 1
                delivered, sstats = simulate_schedule(topo, msgs_arr)
                stats.rounds += sstats.rounds
                stats.link_bytes += sstats.link_bytes
                durR = sstats.rounds
                bstats = None
                if pod_of is not None:
                    # seed-loop bridge accounting: the analytic stats are
                    # exact (== the bridged simulator), so the baseline stays
                    # field-for-field comparable with the compiled engine
                    from .interchip import bridge_program_stats
                    bstats = bridge_program_stats(
                        self._ensure_bridge(), msgs_arr.nbytes, tracer=tr)
                    stats._roll_bridge(bstats)
                    durR += bstats.stall_rounds
                if tr is not None:
                    self._trace_rounds(tr, t0 + 1, msgs_arr.nbytes)
                for (s, d), msgs in per_pair.items():
                    off = 0
                    for v, dpe, dport in msgs:
                        raw = delivered[d, s, off:off + v.nbytes].tobytes()
                        mailbox[(dpe, dport)] = np.frombuffer(raw, v.dtype).reshape(v.shape).copy()
                        off += cfg.flit_framed_bytes(v.nbytes)
            if tr is not None:
                tr.span("scatter", "engine", t0, 1, msgs=len(outbox),
                        bytes=sum(v.nbytes for v, *_ in outbox))
                tr.span("route", "engine", t0 + 1, max(durR, 1),
                        mode="sim_python")
                tr.span("gather", "engine", t0 + 1 + durR, 1)
                tr.span("wave", "noc", t0, durR + 2, wave=iw,
                        msgs=len(outbox))
                tr.clock = t0 + durR + 2
        outs = {f"{pe}.{port.name}": mailbox[(pe, port.name)] for pe, port in g.graph_outputs()}
        reg = get_registry()
        if reg is not None:
            reg.record_noc_stats(stats, mode="sim_python",
                                 topology=type(topo).__name__)
        return outs, stats

    def run_iterative(self, inputs: Mapping[str, Any], feedback, n_iters: int,
                      mode: str = "sim") -> tuple[dict[str, Any], NoCStats]:
        state = dict(inputs)
        total = NoCStats()
        outs: dict[str, Any] = {}
        for _ in range(n_iters):
            outs, st = self.run(state, mode=mode)
            total.add(st)
            for src, dst in feedback:
                state[dst] = outs[src]
        return outs, total
