"""Routing schedules: execute a Topology's all-to-all as JAX collectives.

The paper's CONNECT routers move flits hop by hop at runtime.  On TPU the
equivalent is a *static* schedule of neighbor exchanges compiled into the
program: every round is one ``lax.ppermute`` (= one ICI hop for every node in
parallel); the fat-tree/crossbar case is a single fused ``lax.all_to_all``.

All functions here run *inside* ``jax.shard_map`` and operate on the
per-device view: ``x`` has shape ``(n, *chunk)`` where ``x[d]`` is the message
this node addresses to node ``d``.  They return ``(n, *chunk)`` where
``out[s]`` is the message received from node ``s``.  The semantics of every
variant is exactly the device transpose (``transpose_oracle``) — property
tested in tests/test_routing*.py.

A pure-numpy round-by-round simulator (``simulate_schedule``) executes the
same schedules without devices; benchmarks use it so that measured time scales
with rounds x bytes like the paper's Table V.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size
from .topology import FatTree, Mesh2D, Ring, Topology, Torus2D


# ---------------------------------------------------------------------------
# shard_map collectives (per-device view)
# ---------------------------------------------------------------------------

def transpose_oracle(x: jax.Array, axis_name: str) -> jax.Array:
    """Reference semantics: fused all_to_all (what the schedules must equal)."""
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0)


def _fwd_perm(n: int, wrap: bool) -> list[tuple[int, int]]:
    return [(s, (s + 1) % n) for s in range(n) if wrap or s + 1 < n]


def _bwd_perm(n: int, wrap: bool) -> list[tuple[int, int]]:
    return [(s, (s - 1) % n) for s in range(n) if wrap or s - 1 >= 0]


def _put(out: jax.Array, src, val: jax.Array, valid) -> jax.Array:
    """out[src] = val where valid (dynamic index, masked)."""
    src_c = jnp.clip(src, 0, out.shape[0] - 1)
    cur = lax.dynamic_index_in_dim(out, src_c, 0, keepdims=False)
    new = jnp.where(valid, val, cur)
    return lax.dynamic_update_index_in_dim(out, new, src_c, 0)


def ring_all_to_all_unidir(x: jax.Array, axis_name: str) -> jax.Array:
    """Paper-faithful unidirectional ring rotation: n-1 rounds."""
    n = axis_size(axis_name)
    i = lax.axis_index(axis_name)
    me = lax.dynamic_index_in_dim(x, i, 0, keepdims=False)
    out = _put(jnp.zeros_like(x), i, me, True)
    buf = x
    for t in range(1, n):
        buf = lax.ppermute(buf, axis_name, _fwd_perm(n, wrap=True))
        # after t forward rotations this node holds node (i-t)'s buffer;
        # extract the message it addressed to us.
        val = lax.dynamic_index_in_dim(buf, i, 0, keepdims=False)
        out = _put(out, (i - t) % n, val, True)
    return out


def line_all_to_all(x: jax.Array, axis_name: str, wrap: bool) -> jax.Array:
    """Bidirectional 1D exchange.  wrap=True → torus ring (⌈n/2⌉-ish rounds,
    both directions concurrently); wrap=False → mesh line (n-1 rounds)."""
    n = axis_size(axis_name)
    i = lax.axis_index(axis_name)
    me = lax.dynamic_index_in_dim(x, i, 0, keepdims=False)
    out = _put(jnp.zeros_like(x), i, me, True)
    if n == 1:
        return out
    fwd_steps = n // 2 if wrap else n - 1
    bwd_steps = (n - 1) // 2 if wrap else n - 1
    fbuf, bbuf = x, x
    for t in range(1, max(fwd_steps, bwd_steps) + 1):
        if t <= fwd_steps:
            fbuf = lax.ppermute(fbuf, axis_name, _fwd_perm(n, wrap))
            src = (i - t) % n if wrap else i - t
            val = lax.dynamic_index_in_dim(fbuf, i, 0, keepdims=False)
            out = _put(out, src, val, True if wrap else src >= 0)
        if t <= bwd_steps:
            bbuf = lax.ppermute(bbuf, axis_name, _bwd_perm(n, wrap))
            src = (i + t) % n if wrap else i + t
            val = lax.dynamic_index_in_dim(bbuf, i, 0, keepdims=False)
            out = _put(out, src, val, True if wrap else src < n)
    return out


def grid_all_to_all(x: jax.Array, axis_x: str, axis_y: str, wrap: bool) -> jax.Array:
    """Factorized 2D exchange (dimension-ordered routing, like XY routing in
    the paper's mesh/torus NoCs).  ``x``: (n, *chunk), destination linear index
    d = dy*rx + dx;  returns source-linear-indexed result."""
    rx = axis_size(axis_x)
    ry = axis_size(axis_y)
    c = x.shape[1:]
    b = x.reshape(ry, rx, *c)          # (dy, dx, *c)
    b = jnp.moveaxis(b, 1, 0)          # (dx, dy, *c)
    b = line_all_to_all(b, axis_x, wrap)   # (sx, dy, *c)
    b = jnp.moveaxis(b, 1, 0)          # (dy, sx, *c)
    b = line_all_to_all(b, axis_y, wrap)   # (sy, sx, *c)
    return b.reshape(ry * rx, *c)      # source linear index sy*rx + sx


def crossbar_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    """Fat-tree / ideal crossbar: single fused all_to_all."""
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0)


def topology_axes(topo: Topology) -> tuple[tuple[str, int], ...]:
    """Mesh axes a topology's schedule needs (NoC executor builds this mesh)."""
    if isinstance(topo, (Torus2D, Mesh2D)):
        return (("noc_y", topo.ry), ("noc_x", topo.rx))
    return (("noc", topo.n_nodes),)


def all_to_all_for(topo: Topology):
    """Return fn(x) usable inside shard_map over ``topology_axes(topo)``."""
    if isinstance(topo, Ring):
        return lambda x: ring_all_to_all_unidir(x, "noc")
    if isinstance(topo, Torus2D):  # subclass of Mesh2D — check first
        return lambda x: grid_all_to_all(x, "noc_x", "noc_y", wrap=True)
    if isinstance(topo, Mesh2D):
        return lambda x: grid_all_to_all(x, "noc_x", "noc_y", wrap=False)
    if isinstance(topo, FatTree):
        return lambda x: crossbar_all_to_all(x, "noc")
    raise TypeError(f"no schedule for {type(topo).__name__}")


# ---------------------------------------------------------------------------
# numpy schedule simulator (no devices; benchmark + oracle for tests)
# ---------------------------------------------------------------------------

class ScheduleStats:
    def __init__(self):
        self.rounds = 0
        self.link_bytes = 0

    def __repr__(self):
        return f"ScheduleStats(rounds={self.rounds}, link_bytes={self.link_bytes})"


def _sim_line(buf: np.ndarray, wrap: bool, stats: ScheduleStats) -> np.ndarray:
    """buf: (n_nodes, n_dst_axis, *c) per-node buffers; returns (n, n_src, *c).

    Executes the same forward/backward rotation schedule round by round,
    physically moving buffers (so wall time ∝ rounds × bytes)."""
    n = buf.shape[0]
    out = np.zeros_like(buf)
    for i in range(n):
        out[i, i] = buf[i, i]
    if n == 1:
        return out
    fwd_steps = n // 2 if wrap else n - 1
    bwd_steps = (n - 1) // 2 if wrap else n - 1
    fbuf, bbuf = buf.copy(), buf.copy()
    for t in range(1, max(fwd_steps, bwd_steps) + 1):
        stats.rounds += 1
        if t <= fwd_steps:
            fbuf = np.roll(fbuf, 1, axis=0)
            if not wrap:
                fbuf[0] = 0
            stats.link_bytes += fbuf.nbytes - (fbuf.nbytes // n if not wrap else 0)
            for i in range(n):
                src = (i - t) % n if wrap else i - t
                if 0 <= src < n:
                    out[i, src] = fbuf[i, i]
        if t <= bwd_steps:
            bbuf = np.roll(bbuf, -1, axis=0)
            if not wrap:
                bbuf[-1] = 0
            stats.link_bytes += bbuf.nbytes - (bbuf.nbytes // n if not wrap else 0)
            for i in range(n):
                src = (i + t) % n if wrap else i + t
                if 0 <= src < n:
                    out[i, src] = bbuf[i, i]
    return out


def _sim_ring_unidir(buf: np.ndarray, stats: ScheduleStats) -> np.ndarray:
    n = buf.shape[0]
    out = np.zeros_like(buf)
    for i in range(n):
        out[i, i] = buf[i, i]
    fbuf = buf.copy()
    for t in range(1, n):
        stats.rounds += 1
        fbuf = np.roll(fbuf, 1, axis=0)
        stats.link_bytes += fbuf.nbytes
        for i in range(n):
            out[i, (i - t) % n] = fbuf[i, i]
    return out


def simulate_schedule(topo: Topology, msgs: np.ndarray, *,
                      batched: bool = False) -> tuple[np.ndarray, ScheduleStats]:
    """msgs: (n_src, n_dst, *c).  Returns (delivered (n_dst, n_src, *c), stats).

    Semantics oracle: delivered == msgs.swapaxes(0, 1).

    With ``batched=True`` msgs carries a leading batch axis ``(B, n, n, *c)``
    and B independent message sets move through the topology in ONE
    round-by-round simulation (the batch rides along as payload, so rounds are
    counted once while link_bytes scales with B).  Returns ``(B, n, n, *c)``
    delivered, i.e. ``msgs.swapaxes(1, 2)``."""
    if batched:
        assert msgs.ndim >= 3, "batched msgs must be (B, n_src, n_dst, *c)"
        inner = np.ascontiguousarray(np.moveaxis(msgs, 0, 2))   # (n, n, B, *c)
        delivered, stats = simulate_schedule(topo, inner)
        return np.ascontiguousarray(np.moveaxis(delivered, 2, 0)), stats
    n = topo.n_nodes
    assert msgs.shape[0] == n and msgs.shape[1] == n
    stats = ScheduleStats()
    if isinstance(topo, FatTree):
        stats.rounds = 1
        stats.link_bytes = int(msgs.nbytes * (n - 1) / n)
        return msgs.swapaxes(0, 1).copy(), stats
    if isinstance(topo, Ring):
        return _sim_ring_unidir(msgs, stats), stats
    if isinstance(topo, (Torus2D, Mesh2D)):
        wrap = isinstance(topo, Torus2D)
        rx, ry = topo.rx, topo.ry
        c = msgs.shape[2:]
        cflat = int(np.prod(c, dtype=np.int64)) if c else 1
        # node linear index = y*rx + x; XY dimension-ordered routing.
        m = msgs.reshape(ry, rx, ry, rx, *c)            # [sy, sx, dy, dx, *c]
        # Phase X: every row executes the line schedule concurrently — fold all
        # non-(sx,dx) indices into the payload so one _sim_line call = one
        # parallel phase (stats counted once, bytes include all rows' links).
        b = np.moveaxis(m, (1, 3), (0, 1))              # [sx, dx, sy, dy, *c]
        b = _sim_line(np.ascontiguousarray(b).reshape(rx, rx, -1), wrap, stats)
        b = b.reshape(rx, rx, ry, ry, *c)               # [dx(node), sx, sy, dy, *c]
        # Phase Y: every column concurrently, keyed by dy.
        b = np.moveaxis(b, (2, 3), (0, 1))              # [sy, dy, dx, sx, *c]
        b = _sim_line(np.ascontiguousarray(b).reshape(ry, ry, -1), wrap, stats)
        b = b.reshape(ry, ry, rx, rx, *c)               # [dy(node), sy, dx, sx, *c]
        out = np.moveaxis(b, (0, 2, 1, 3), (0, 1, 2, 3))  # [dy, dx, sy, sx, *c]
        return np.ascontiguousarray(out).reshape(n, n, *c), stats
    raise TypeError(f"no simulator for {type(topo).__name__}")
