"""Routing schedules: execute a Topology's all-to-all as JAX collectives.

The paper's CONNECT routers move flits hop by hop at runtime.  On TPU the
equivalent is a *static* schedule of neighbor exchanges compiled into the
program: every round is one ``lax.ppermute`` (= one ICI hop for every node in
parallel); the fat-tree/crossbar case is a single fused ``lax.all_to_all``.

All functions here run *inside* ``jax.shard_map`` and operate on the
per-device view: ``x`` has shape ``(n, *chunk)`` where ``x[d]`` is the message
this node addresses to node ``d``.  They return ``(n, *chunk)`` where
``out[s]`` is the message received from node ``s``.  The semantics of every
variant is exactly the device transpose (``transpose_oracle``) — property
tested in tests/test_routing*.py.

A pure-numpy round-by-round simulator (``simulate_schedule``) executes the
same schedules without devices; benchmarks use it so that measured time scales
with rounds x bytes like the paper's Table V.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

import dataclasses
from typing import Optional

from ..compat import axis_size
from .topology import (AxisSchedule, FatTree, Mesh2D, Ring, Topology, Torus2D,
                       bwd_pairs, fwd_pairs)


# ---------------------------------------------------------------------------
# shard_map collectives (per-device view)
# ---------------------------------------------------------------------------

def transpose_oracle(x: jax.Array, axis_name: str) -> jax.Array:
    """Reference semantics: fused all_to_all (what the schedules must equal)."""
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0)


def _fwd_perm(n: int, wrap: bool) -> list[tuple[int, int]]:
    return list(fwd_pairs(n, wrap))


def _bwd_perm(n: int, wrap: bool) -> list[tuple[int, int]]:
    return list(bwd_pairs(n, wrap))


def _put(out: jax.Array, src, val: jax.Array, valid) -> jax.Array:
    """out[src] = val where valid (dynamic index, masked)."""
    src_c = jnp.clip(src, 0, out.shape[0] - 1)
    cur = lax.dynamic_index_in_dim(out, src_c, 0, keepdims=False)
    new = jnp.where(valid, val, cur)
    return lax.dynamic_update_index_in_dim(out, new, src_c, 0)


def ring_all_to_all_unidir(x: jax.Array, axis_name: str) -> jax.Array:
    """Paper-faithful unidirectional ring rotation: n-1 rounds."""
    n = axis_size(axis_name)
    i = lax.axis_index(axis_name)
    me = lax.dynamic_index_in_dim(x, i, 0, keepdims=False)
    out = _put(jnp.zeros_like(x), i, me, True)
    buf = x
    for t in range(1, n):
        buf = lax.ppermute(buf, axis_name, _fwd_perm(n, wrap=True))
        # after t forward rotations this node holds node (i-t)'s buffer;
        # extract the message it addressed to us.
        val = lax.dynamic_index_in_dim(buf, i, 0, keepdims=False)
        out = _put(out, (i - t) % n, val, True)
    return out


def line_all_to_all(x: jax.Array, axis_name: str, wrap: bool) -> jax.Array:
    """Bidirectional 1D exchange.  wrap=True → torus ring (⌈n/2⌉-ish rounds,
    both directions concurrently); wrap=False → mesh line (n-1 rounds)."""
    n = axis_size(axis_name)
    i = lax.axis_index(axis_name)
    me = lax.dynamic_index_in_dim(x, i, 0, keepdims=False)
    out = _put(jnp.zeros_like(x), i, me, True)
    if n == 1:
        return out
    fwd_steps = n // 2 if wrap else n - 1
    bwd_steps = (n - 1) // 2 if wrap else n - 1
    fbuf, bbuf = x, x
    for t in range(1, max(fwd_steps, bwd_steps) + 1):
        if t <= fwd_steps:
            fbuf = lax.ppermute(fbuf, axis_name, _fwd_perm(n, wrap))
            src = (i - t) % n if wrap else i - t
            val = lax.dynamic_index_in_dim(fbuf, i, 0, keepdims=False)
            out = _put(out, src, val, True if wrap else src >= 0)
        if t <= bwd_steps:
            bbuf = lax.ppermute(bbuf, axis_name, _bwd_perm(n, wrap))
            src = (i + t) % n if wrap else i + t
            val = lax.dynamic_index_in_dim(bbuf, i, 0, keepdims=False)
            out = _put(out, src, val, True if wrap else src < n)
    return out


def grid_all_to_all(x: jax.Array, axis_x: str, axis_y: str, wrap: bool) -> jax.Array:
    """Factorized 2D exchange (dimension-ordered routing, like XY routing in
    the paper's mesh/torus NoCs).  ``x``: (n, *chunk), destination linear index
    d = dy*rx + dx;  returns source-linear-indexed result."""
    rx = axis_size(axis_x)
    ry = axis_size(axis_y)
    c = x.shape[1:]
    b = x.reshape(ry, rx, *c)          # (dy, dx, *c)
    b = jnp.moveaxis(b, 1, 0)          # (dx, dy, *c)
    b = line_all_to_all(b, axis_x, wrap)   # (sx, dy, *c)
    b = jnp.moveaxis(b, 1, 0)          # (dy, sx, *c)
    b = line_all_to_all(b, axis_y, wrap)   # (sy, sx, *c)
    return b.reshape(ry * rx, *c)      # source linear index sy*rx + sx


def crossbar_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    """Fat-tree / ideal crossbar: single fused all_to_all."""
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0)


# ---------------------------------------------------------------------------
# schedule → ppermute-round compiler (hop decomposition)
# ---------------------------------------------------------------------------
#
# A topology's all-to-all is compiled into an explicit, value-independent
# :class:`RouteProgram`: a sequence of per-axis phases (dimension-ordered XY
# routing), each decomposed into rounds of single-hop neighbor permutations.
# Every round moves at most two rotating buffers (forward/backward direction)
# one hop via ``lax.ppermute`` and commits the messages that have reached their
# destination column, using static per-node source tables.  The same program
# drives three interpreters:
#
# * :func:`run_route_program`      — inside ``shard_map`` on a device mesh
#                                    (the NoC executor's ``mode="spmd"``);
# * :func:`simulate_route_program` — pure numpy, round-by-round (property
#                                    tests without devices);
# * :func:`route_program_stats`    — analytic rounds/link-bytes, matching the
#                                    round-by-round simulator exactly.

@dataclasses.dataclass(frozen=True)
class HopMove:
    """One single-hop buffer rotation inside a round.

    ``buf``       — which rotating buffer moves (0 = forward, 1 = backward);
    ``perm``      — the ``lax.ppermute`` (src, dst) neighbor pairs;
    ``src_table`` — per node ``i`` along the axis: the source node whose
                    message addressed to ``i`` arrives with this hop
                    (-1: nothing to commit at ``i``).
    """

    buf: int
    perm: tuple[tuple[int, int], ...]
    src_table: tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class PermuteRound:
    """One synchronous NoC round: every node sends one buffer per link
    direction concurrently (1 move for unidirectional, 2 for bidirectional)."""

    moves: tuple[HopMove, ...]


@dataclasses.dataclass(frozen=True)
class LinePhase:
    """Hop-decomposed all-to-all along one mesh axis."""

    sched: AxisSchedule
    rounds: tuple[PermuteRound, ...]


@dataclasses.dataclass(frozen=True)
class RouteProgram:
    """Compiled routing schedule of a topology's all-to-all exchange."""

    topo_name: str
    n_nodes: int
    axes: tuple[tuple[str, int], ...]    # device-mesh axes (= topology_axes)
    phases: tuple[LinePhase, ...]        # empty → fused crossbar all_to_all

    @property
    def fused(self) -> bool:
        return not self.phases

    @property
    def n_rounds(self) -> int:
        return 1 if self.fused else sum(len(p.rounds) for p in self.phases)


def _compile_line_phase(sched: AxisSchedule) -> LinePhase:
    n = sched.size
    rounds = []
    for t in range(1, max(sched.fwd_steps, sched.bwd_steps) + 1):
        moves = []
        if t <= sched.fwd_steps:
            src = tuple((i - t) % n if sched.wrap else (i - t if i - t >= 0 else -1)
                        for i in range(n))
            moves.append(HopMove(0, sched.fwd_pairs(), src))
        if t <= sched.bwd_steps:
            src = tuple((i + t) % n if sched.wrap else (i + t if i + t < n else -1)
                        for i in range(n))
            moves.append(HopMove(1, sched.bwd_pairs(), src))
        rounds.append(PermuteRound(tuple(moves)))
    return LinePhase(sched, tuple(rounds))


def compile_routes(topo: Topology) -> RouteProgram:
    """Compile a topology's all-to-all into an explicit ppermute-round program."""
    phases = tuple(_compile_line_phase(s) for s in topo.axis_schedules())
    return RouteProgram(topo.name, topo.n_nodes, topology_axes(topo), phases)


def _line_exchange_compiled(x: jax.Array, phase: LinePhase,
                            axis_name: Optional[str] = None,
                            coord: Optional[jax.Array] = None,
                            expand=None, transfer=None) -> jax.Array:
    """Execute one compiled line phase on the per-device view (inside
    shard_map): x is (n, *chunk) destination-indexed, returns source-indexed.

    By default the phase runs over its own mesh axis (``phase.sched.axis``).
    With ``axis_name``/``coord``/``expand`` it runs *linearized* over a single
    flat device axis that embeds the phase axis: ``coord`` is this device's
    position along the phase axis and ``expand`` maps the phase's per-axis
    (src, dst) hop pairs to full-axis pairs (every row/column concurrently).

    ``transfer(buf, pairs)`` overrides the hop transport (default: one
    ``lax.ppermute``).  `core.interchip` uses it to funnel pod-crossing hops
    through quasi-SERDES bridge endpoints while intra-pod hops stay plain
    ppermutes; the pairs it receives are the *expanded* (full-axis) ones, i.e.
    global node ids in linearized mode."""
    sched = phase.sched
    name = axis_name or sched.axis
    i = lax.axis_index(name) if coord is None else coord
    me = lax.dynamic_index_in_dim(x, i, 0, keepdims=False)
    out = _put(jnp.zeros_like(x), i, me, True)
    bufs = [x, x]
    for rnd in phase.rounds:
        for mv in rnd.moves:
            perm = expand(mv.perm) if expand is not None else list(mv.perm)
            if transfer is None:
                bufs[mv.buf] = lax.ppermute(bufs[mv.buf], name, perm)
            else:
                bufs[mv.buf] = transfer(bufs[mv.buf], perm)
            src = jnp.asarray(mv.src_table, jnp.int32)[i]
            val = lax.dynamic_index_in_dim(bufs[mv.buf], i, 0, keepdims=False)
            out = _put(out, src, val, src >= 0)
    return out


def run_route_program(x: jax.Array, prog: RouteProgram,
                      axis_name: Optional[str] = None,
                      transfer=None) -> jax.Array:
    """Execute a compiled RouteProgram inside ``shard_map``.

    Same contract as the handwritten schedules: ``x`` is the per-device
    ``(n, *chunk)`` destination-indexed view; returns the source-indexed
    ``(n, *chunk)`` received view (== :func:`transpose_oracle`).

    With ``axis_name=None`` the program runs over its own mesh axes
    (``prog.axes`` — the NoC executor's ``mode="spmd"``).  Passing an
    ``axis_name`` runs the *same* program linearized over one flat device
    axis of size ``prog.n_nodes`` (node linear id = ``y*rx + x`` for 2D
    topologies): each per-axis hop permutation is statically expanded to the
    full axis so every row/column exchanges concurrently, exactly one
    ``lax.ppermute`` per hop move.  This is how callers embedded in an
    existing mesh (e.g. MoE token dispatch over the ``model`` axis) route
    through the topology without building a dedicated NoC mesh.

    ``transfer`` (see :func:`_line_exchange_compiled`) swaps the hop transport
    and requires ``axis_name`` (linearized execution) so its pairs are global
    node ids."""
    if transfer is not None and axis_name is None:
        raise ValueError("transfer= requires linearized execution (axis_name)")
    if prog.fused:
        if transfer is not None:
            # a fused crossbar has no hop moves to re-transport; silently
            # ignoring the hook would execute cut links un-bridged
            raise ValueError("transfer= is not supported for fused programs; "
                             "use interchip.run_bridged_program, which "
                             "handles the crossbar case itself")
        name = axis_name or prog.axes[0][0]
        return lax.all_to_all(x, name, split_axis=0, concat_axis=0)
    if len(prog.phases) == 1:
        return _line_exchange_compiled(x, prog.phases[0], axis_name=axis_name,
                                       transfer=transfer)
    # 2D XY routing: factorized exchange, same data motion as grid_all_to_all
    (_, ry), (_, rx) = prog.axes          # axes = (noc_y, noc_x)
    phase_x, phase_y = prog.phases        # phases ordered X then Y
    cx = cy = None
    ex_x = ex_y = None
    if axis_name is not None:
        i = lax.axis_index(axis_name)
        cx, cy = i % rx, i // rx

        def ex_x(pairs):
            return [(y * rx + s, y * rx + d)
                    for y in range(ry) for s, d in pairs]

        def ex_y(pairs):
            return [(s * rx + xc, d * rx + xc)
                    for xc in range(rx) for s, d in pairs]
    c = x.shape[1:]
    b = x.reshape(ry, rx, *c)             # (dy, dx, *c)
    b = jnp.moveaxis(b, 1, 0)             # (dx, dy, *c)
    b = _line_exchange_compiled(b, phase_x, axis_name, cx, ex_x,
                                transfer)                          # (sx, dy, *c)
    b = jnp.moveaxis(b, 1, 0)             # (dy, sx, *c)
    b = _line_exchange_compiled(b, phase_y, axis_name, cy, ex_y,
                                transfer)                          # (sy, sx, *c)
    return b.reshape(ry * rx, *c)         # source linear index sy*rx + sx


def _np_line_compiled(buf: np.ndarray, phase: LinePhase,
                      stats: "ScheduleStats") -> np.ndarray:
    """Numpy interpreter of one compiled line phase (mirrors _sim_line)."""
    n = phase.sched.size
    out = np.zeros_like(buf)
    for i in range(n):
        out[i, i] = buf[i, i]
    bufs = [buf.copy(), buf.copy()]
    for rnd in phase.rounds:
        stats.rounds += 1
        for mv in rnd.moves:
            cur = bufs[mv.buf]
            nxt = np.zeros_like(cur)
            for s, d in mv.perm:
                nxt[d] = cur[s]
                stats.link_bytes += cur[s].nbytes
            bufs[mv.buf] = nxt
            for i in range(n):
                if mv.src_table[i] >= 0:
                    out[i, mv.src_table[i]] = nxt[i, i]
    return out


def simulate_route_program(prog: RouteProgram,
                           msgs: np.ndarray) -> tuple[np.ndarray, "ScheduleStats"]:
    """Round-by-round numpy execution of a compiled program (no devices).

    msgs: (n_src, n_dst, *c); returns (delivered (n_dst, n_src, *c), stats).
    Must be bit-identical to :func:`simulate_schedule` on the same topology —
    the compiled program and the handwritten simulator are two lowerings of
    the same schedule."""
    n = prog.n_nodes
    assert msgs.shape[0] == n and msgs.shape[1] == n
    stats = ScheduleStats()
    if prog.fused:
        return msgs.swapaxes(0, 1).copy(), route_program_stats(prog, msgs.nbytes)
    if len(prog.phases) == 1:
        return _np_line_compiled(msgs, prog.phases[0], stats), stats
    (_, ry), (_, rx) = prog.axes
    phase_x, phase_y = prog.phases
    c = msgs.shape[2:]
    m = msgs.reshape(ry, rx, ry, rx, *c)            # [sy, sx, dy, dx, *c]
    b = np.moveaxis(m, (1, 3), (0, 1))              # [sx, dx, sy, dy, *c]
    b = _np_line_compiled(np.ascontiguousarray(b).reshape(rx, rx, -1),
                          phase_x, stats)
    b = b.reshape(rx, rx, ry, ry, *c)               # [dx(node), sx, sy, dy, *c]
    b = np.moveaxis(b, (2, 3), (0, 1))              # [sy, dy, dx, sx, *c]
    b = _np_line_compiled(np.ascontiguousarray(b).reshape(ry, ry, -1),
                          phase_y, stats)
    b = b.reshape(ry, ry, rx, rx, *c)               # [dy(node), sy, dx, sx, *c]
    out = np.moveaxis(b, (0, 2, 1, 3), (0, 1, 2, 3))
    return np.ascontiguousarray(out).reshape(n, n, *c), stats


def route_program_stats(prog: RouteProgram, cube_nbytes: int) -> "ScheduleStats":
    """Analytic ScheduleStats for moving one (n, n, ...) message cube of
    ``cube_nbytes`` total bytes through a compiled program.

    Exactly matches what :func:`simulate_schedule` / the round-by-round
    interpreter count (the spmd executor uses this so NoCStats stay identical
    to ``mode="sim"`` without re-running the numpy simulator)."""
    stats = ScheduleStats()
    n = prog.n_nodes
    if prog.fused:
        stats.rounds = 1
        stats.link_bytes = int(cube_nbytes * (n - 1) / n)
        return stats
    for phase in prog.phases:
        per_row = cube_nbytes // phase.sched.size
        for rnd in phase.rounds:
            stats.rounds += 1
            for mv in rnd.moves:
                stats.link_bytes += per_row * len(mv.perm)
    return stats


def topology_axes(topo: Topology) -> tuple[tuple[str, int], ...]:
    """Mesh axes a topology's schedule needs (NoC executor builds this mesh)."""
    if isinstance(topo, (Torus2D, Mesh2D)):
        return (("noc_y", topo.ry), ("noc_x", topo.rx))
    return (("noc", topo.n_nodes),)


def all_to_all_for(topo: Topology):
    """Return fn(x) usable inside shard_map over ``topology_axes(topo)``."""
    if isinstance(topo, Ring):
        return lambda x: ring_all_to_all_unidir(x, "noc")
    if isinstance(topo, Torus2D):  # subclass of Mesh2D — check first
        return lambda x: grid_all_to_all(x, "noc_x", "noc_y", wrap=True)
    if isinstance(topo, Mesh2D):
        return lambda x: grid_all_to_all(x, "noc_x", "noc_y", wrap=False)
    if isinstance(topo, FatTree):
        return lambda x: crossbar_all_to_all(x, "noc")
    raise TypeError(f"no schedule for {type(topo).__name__}")


# ---------------------------------------------------------------------------
# numpy schedule simulator (no devices; benchmark + oracle for tests)
# ---------------------------------------------------------------------------

class ScheduleStats:
    def __init__(self):
        self.rounds = 0
        self.link_bytes = 0

    def __repr__(self):
        return f"ScheduleStats(rounds={self.rounds}, link_bytes={self.link_bytes})"


def _sim_line(buf: np.ndarray, wrap: bool, stats: ScheduleStats) -> np.ndarray:
    """buf: (n_nodes, n_dst_axis, *c) per-node buffers; returns (n, n_src, *c).

    Executes the same forward/backward rotation schedule round by round,
    physically moving buffers (so wall time ∝ rounds × bytes)."""
    n = buf.shape[0]
    out = np.zeros_like(buf)
    for i in range(n):
        out[i, i] = buf[i, i]
    if n == 1:
        return out
    fwd_steps = n // 2 if wrap else n - 1
    bwd_steps = (n - 1) // 2 if wrap else n - 1
    fbuf, bbuf = buf.copy(), buf.copy()
    for t in range(1, max(fwd_steps, bwd_steps) + 1):
        stats.rounds += 1
        if t <= fwd_steps:
            fbuf = np.roll(fbuf, 1, axis=0)
            if not wrap:
                fbuf[0] = 0
            stats.link_bytes += fbuf.nbytes - (fbuf.nbytes // n if not wrap else 0)
            for i in range(n):
                src = (i - t) % n if wrap else i - t
                if 0 <= src < n:
                    out[i, src] = fbuf[i, i]
        if t <= bwd_steps:
            bbuf = np.roll(bbuf, -1, axis=0)
            if not wrap:
                bbuf[-1] = 0
            stats.link_bytes += bbuf.nbytes - (bbuf.nbytes // n if not wrap else 0)
            for i in range(n):
                src = (i + t) % n if wrap else i + t
                if 0 <= src < n:
                    out[i, src] = bbuf[i, i]
    return out


def _sim_ring_unidir(buf: np.ndarray, stats: ScheduleStats) -> np.ndarray:
    n = buf.shape[0]
    out = np.zeros_like(buf)
    for i in range(n):
        out[i, i] = buf[i, i]
    fbuf = buf.copy()
    for t in range(1, n):
        stats.rounds += 1
        fbuf = np.roll(fbuf, 1, axis=0)
        stats.link_bytes += fbuf.nbytes
        for i in range(n):
            out[i, (i - t) % n] = fbuf[i, i]
    return out


def simulate_schedule(topo: Topology, msgs: np.ndarray, *,
                      batched: bool = False) -> tuple[np.ndarray, ScheduleStats]:
    """msgs: (n_src, n_dst, *c).  Returns (delivered (n_dst, n_src, *c), stats).

    Semantics oracle: delivered == msgs.swapaxes(0, 1).

    With ``batched=True`` msgs carries a leading batch axis ``(B, n, n, *c)``
    and B independent message sets move through the topology in ONE
    round-by-round simulation (the batch rides along as payload, so rounds are
    counted once while link_bytes scales with B).  Returns ``(B, n, n, *c)``
    delivered, i.e. ``msgs.swapaxes(1, 2)``."""
    if batched:
        assert msgs.ndim >= 3, "batched msgs must be (B, n_src, n_dst, *c)"
        inner = np.ascontiguousarray(np.moveaxis(msgs, 0, 2))   # (n, n, B, *c)
        delivered, stats = simulate_schedule(topo, inner)
        return np.ascontiguousarray(np.moveaxis(delivered, 2, 0)), stats
    n = topo.n_nodes
    assert msgs.shape[0] == n and msgs.shape[1] == n
    stats = ScheduleStats()
    if isinstance(topo, FatTree):
        stats.rounds = 1
        stats.link_bytes = int(msgs.nbytes * (n - 1) / n)
        return msgs.swapaxes(0, 1).copy(), stats
    if isinstance(topo, Ring):
        return _sim_ring_unidir(msgs, stats), stats
    if isinstance(topo, (Torus2D, Mesh2D)):
        wrap = isinstance(topo, Torus2D)
        rx, ry = topo.rx, topo.ry
        c = msgs.shape[2:]
        # node linear index = y*rx + x; XY dimension-ordered routing.
        m = msgs.reshape(ry, rx, ry, rx, *c)            # [sy, sx, dy, dx, *c]
        # Phase X: every row executes the line schedule concurrently — fold all
        # non-(sx,dx) indices into the payload so one _sim_line call = one
        # parallel phase (stats counted once, bytes include all rows' links).
        b = np.moveaxis(m, (1, 3), (0, 1))              # [sx, dx, sy, dy, *c]
        b = _sim_line(np.ascontiguousarray(b).reshape(rx, rx, -1), wrap, stats)
        b = b.reshape(rx, rx, ry, ry, *c)               # [dx(node), sx, sy, dy, *c]
        # Phase Y: every column concurrently, keyed by dy.
        b = np.moveaxis(b, (2, 3), (0, 1))              # [sy, dy, dx, sx, *c]
        b = _sim_line(np.ascontiguousarray(b).reshape(ry, ry, -1), wrap, stats)
        b = b.reshape(ry, ry, rx, rx, *c)               # [dy(node), sy, dx, sx, *c]
        out = np.moveaxis(b, (0, 2, 1, 3), (0, 1, 2, 3))  # [dy, dx, sy, sx, *c]
        return np.ascontiguousarray(out).reshape(n, n, *c), stats
    raise TypeError(f"no simulator for {type(topo).__name__}")
