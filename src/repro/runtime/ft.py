"""Fault-tolerant step runner: checkpoint/restart, bounded retries,
failure injection, straggler accounting.

TPU-pod reality this models: SPMD training is synchronous, so node failure
manifests as a failed/hung step on *every* host; the recovery protocol is
(1) abort the step, (2) rebuild the device mesh (possibly smaller — see
runtime.elastic), (3) restore the last committed checkpoint, (4) resume from
the data pipeline's step counter (deterministic batches make this replay
exact).  The runner drives that protocol and is unit-tested with injected
failures (tests/test_ft.py).

Straggler mitigation: with synchronous collectives a straggler is invisible
inside a step; the lever is *between* steps.  The runner keeps an EWMA of
step wall-time; a step exceeding ``straggler_factor``× the EWMA is logged and
counted, and after ``straggler_patience`` consecutive slow steps the runner
invokes ``on_straggler`` (production: re-shard data away from the slow host /
request node replacement; here: a hook + test assertion).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from ..checkpoint import CheckpointManager


class StepFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FTConfig:
    max_failures: int = 3
    checkpoint_every: int = 50
    straggler_factor: float = 2.5
    straggler_patience: int = 3
    ewma: float = 0.9


@dataclasses.dataclass
class RunStats:
    steps: int = 0
    failures: int = 0
    restores: int = 0
    stragglers: int = 0
    straggler_events: int = 0
    ewma_step_s: float = 0.0


class ResilientRunner:
    """Drives `state = step_fn(state, batch)` with checkpoint/restart."""

    def __init__(self, step_fn: Callable, ckpt: CheckpointManager, cfg: FTConfig,
                 on_straggler: Optional[Callable[[int], None]] = None,
                 fail_injector: Optional[Callable[[int], None]] = None):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.cfg = cfg
        self.on_straggler = on_straggler
        self.fail_injector = fail_injector
        self.stats = RunStats()
        self._slow_streak = 0

    def run(self, state, pipeline, n_steps: int, start_step: int = 0):
        """pipeline must expose batch_at(step) (deterministic replay)."""
        step = start_step
        failures = 0
        while step < n_steps:
            t0 = time.monotonic()
            try:
                if self.fail_injector is not None:
                    self.fail_injector(step)  # may raise StepFailure
                batch = pipeline.batch_at(step)
                state = self.step_fn(state, batch)
                self.stats.steps += 1
            except StepFailure:
                failures += 1
                self.stats.failures += 1
                if failures > self.cfg.max_failures:
                    raise
                # recovery protocol: restore last committed state, replay
                latest = self.ckpt.latest_step()
                if latest is not None:
                    state, step, _ = self.ckpt.restore(state, latest)
                    self.stats.restores += 1
                continue
            failures = 0
            dt = time.monotonic() - t0
            st = self.stats
            st.ewma_step_s = dt if st.ewma_step_s == 0 else (
                self.cfg.ewma * st.ewma_step_s + (1 - self.cfg.ewma) * dt)
            if st.ewma_step_s > 0 and dt > self.cfg.straggler_factor * st.ewma_step_s:
                st.stragglers += 1
                self._slow_streak += 1
                if self._slow_streak >= self.cfg.straggler_patience:
                    st.straggler_events += 1
                    self._slow_streak = 0
                    if self.on_straggler is not None:
                        self.on_straggler(step)
            else:
                self._slow_streak = 0
            step += 1
            if step % self.cfg.checkpoint_every == 0 or step == n_steps:
                self.ckpt.save(step, state)
        self.ckpt.wait()
        return state, self.stats
