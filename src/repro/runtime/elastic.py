"""Elastic scaling: rebuild the mesh when the device pool changes and
reshard state onto it.

A 1000-node job loses nodes; waiting for replacements wastes the fleet.  The
elastic path here: ``factor_mesh`` picks the new (pod, data, model) factoring
from the surviving device count (model axis preserved if possible — params
resharding over a changed model axis is the expensive case), ``remesh_plan``
maps the old param PartitionSpecs onto the new mesh, and
``CheckpointManager.restore(shardings=...)`` materializes state on the new
mesh.  Demonstrated end-to-end on fake CPU devices in tests/test_elastic.py
(16 devices → 8 devices → training resumes with identical loss trajectory
modulo batch partitioning).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding


def factor_mesh(n_devices: int, prefer_model: int = 0,
                multi_pod: bool = False) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """Choose a mesh shape for the surviving devices.

    Keeps the model axis at `prefer_model` when it divides n_devices
    (params need no cross-axis reshuffle), else the largest power-of-two
    divisor ≤ sqrt(n)."""
    assert n_devices >= 1
    if prefer_model and n_devices % prefer_model == 0:
        model = prefer_model
    else:
        model = 1
        while model * 2 <= int(np.sqrt(n_devices)) and n_devices % (model * 2) == 0:
            model *= 2
    rest = n_devices // model
    if multi_pod and rest % 2 == 0:
        return (2, rest // 2, model), ("pod", "data", "model")
    return (rest, model), ("data", "model")


def make_mesh_from_devices(devices: Sequence, shape, axes) -> Mesh:
    arr = np.array(devices[: int(np.prod(shape))]).reshape(shape)
    return Mesh(arr, axes)


def remesh_plan(spec_tree, new_mesh: Mesh, rules=None):
    """Param/opt PartitionSpecs -> NamedShardings on the new mesh."""
    from ..core.partition import DEFAULT_RULES
    from ..models.layers import param_pspecs

    rules = rules or DEFAULT_RULES
    pspecs = param_pspecs(spec_tree, rules, new_mesh.axis_names, dict(new_mesh.shape))
    return jax.tree.map(lambda ps: NamedSharding(new_mesh, ps), pspecs)
