from .elastic import factor_mesh, remesh_plan
from .ft import FTConfig, ResilientRunner, StepFailure
