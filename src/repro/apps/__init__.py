"""The paper's three case studies, built on core + kernels."""
from . import bmvm, ldpc, particle_filter
