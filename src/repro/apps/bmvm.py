"""Case study III: GF(2) matrix–vector multiplication, Williams' sub-quadratic
algorithm (paper §VI) — block-Wiedemann-style iterated products A^r·V.

The communication structure is exactly an all-to-all: node i looks up
LUT_i[v_i] and sends word j to node j, which XOR-accumulates — so topology
choice dominates performance (the paper's Table V).  Three realizations:

* ``iterate_kernel``   — single-chip datapath: the Pallas LUT-XOR kernel
                         (BRAM→VMEM adaptation) iterated r times.
* ``iterate_noc_sim``  — PE-per-node TaskGraph on a chosen topology with
                         round-by-round routing stats (Table V reproduction).
* ``iterate_spmd``     — shard_map over real devices: local lookup + the
                         topology's collective schedule + XOR reduce (the
                         production path; exercised in the dry-run + tests).

Folding (paper §VI-B): fold=f gives each PE f sub-vectors with a coalesced
LUT — here simply n/k/f PEs each owning f LUT columns.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..compat import shard_map
from ..core import (NoCExecutor, PE, Port, TaskGraph, cut, make_topology,
                    resolve_placement)
from ..core.routing import all_to_all_for, topology_axes
from ..kernels import ops as kops
from ..kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class BMVMConfig:
    n: int = 64
    k: int = 8
    fold: int = 2
    topology: str = "mesh"

    @property
    def n_sub(self) -> int:           # sub-vectors
        return self.n // self.k

    @property
    def n_pe(self) -> int:            # PEs after folding
        assert self.n_sub % self.fold == 0
        return self.n_sub // self.fold


def preprocess(a_bits: np.ndarray, cfg: BMVMConfig) -> jax.Array:
    """One-time LUT construction (paper Fig. 13): (C, 2^k, R) uint32."""
    return kref.gf2_preprocess(jnp.asarray(a_bits), cfg.k)


def software_ref(a_bits: np.ndarray, v_bits: np.ndarray, r: int) -> np.ndarray:
    """The paper's multithreaded-software analog: direct O(n²) iterated."""
    a = np.asarray(a_bits, np.uint8)
    v = np.asarray(v_bits, np.uint8)
    for _ in range(r):
        v = (v @ a.T) % 2
    return v


def iterate_kernel(lut: jax.Array, v_bits: jax.Array, cfg: BMVMConfig, r: int,
                   use_kernel: bool = True) -> jax.Array:
    """A^r·V via the Pallas kernel; v_bits: (M, n) -> (M, n)."""
    vw = kref.gf2_pack_vector(v_bits, cfg.k).astype(jnp.uint32)

    def body(vw, _):
        return kops.gf2_bmvm(lut, vw, use_kernel=use_kernel), None

    vw, _ = jax.lax.scan(body, vw, None, length=r)
    return kref.gf2_unpack_vector(vw, cfg.k)


# ---------------------------------------------------------------------------
# NoC simulation (Table V reproduction)
# ---------------------------------------------------------------------------

def build_bmvm_graph(lut_np: np.ndarray, cfg: BMVMConfig) -> tuple[TaskGraph, list]:
    """PE_i: lookup its (folded) LUT columns; ACC_j: XOR-accumulate words."""
    C, P, R = lut_np.shape
    npe, f = cfg.n_pe, cfg.fold
    g = TaskGraph("bmvm")
    luts = jnp.asarray(lut_np)

    def mk_lookup(i):
        def fn(**kw):
            v = kw["v"].astype(jnp.uint32)          # (f,) this PE's sub-vectors
            cols = jnp.arange(i * f, (i + 1) * f)
            words = jax.vmap(lambda c, vv: luts[c, vv, :])(cols, v)  # (f, R)
            agg = words[0]
            for t in range(1, f):
                agg = jnp.bitwise_xor(agg, words[t])  # fold-local combine
            return {f"w{j}": agg[j * f:(j + 1) * f] for j in range(npe)}
        return fn

    def acc_fn(**kw):
        vals = [kw[f"in{i}"] for i in range(npe)]
        acc = vals[0]
        for v in vals[1:]:
            acc = jnp.bitwise_xor(acc, v)
        return {"v": acc}

    for i in range(npe):
        g.add(PE(f"lut{i}", mk_lookup(i),
                 (Port("v", (f,), np.uint32),),
                 tuple(Port(f"w{j}", (f,), np.uint32) for j in range(npe))))
    for j in range(npe):
        g.add(PE(f"acc{j}", acc_fn,
                 tuple(Port(f"in{i}", (f,), np.uint32) for i in range(npe)),
                 (Port("v", (f,), np.uint32),)))
    feedback = []
    for i in range(npe):
        for j in range(npe):
            g.connect(f"lut{i}.w{j}", f"acc{j}.in{i}")
        feedback.append((f"acc{i}.v", f"lut{i}.v"))
    return g, feedback


def iterate_noc_sim(lut: jax.Array, v_bits: np.ndarray, cfg: BMVMConfig, r: int,
                    topology: Optional[str] = None, n_nodes: Optional[int] = None,
                    placement="rr", mode: str = "sim",
                    pods: Optional[list[int]] = None, serdes_cfg=None,
                    tracer=None):
    """(decoded vector, NoCStats) — the Table-V measurement path.

    ``placement``: 'rr' | 'greedy' | 'opt' (annealing search, cut-aware when
    ``pods`` is given) or an explicit PE→node mapping.  ``mode``: any
    `NoCExecutor.run` mode — ``"spmd"`` runs the same compiled flit program
    over a device mesh (needs n_nodes devices).  ``pods`` (node→pod) turns on
    partitioned execution: cut links run through quasi-SERDES bridge
    endpoints (``serdes_cfg``), results stay bit-identical and NoCStats gain
    the ``bridge_*`` counters.  ``tracer``: a `repro.telemetry.Tracer` to
    record the run's event timeline (trace↔stats parity guaranteed)."""
    from ..core.serdes import QuasiSerdesConfig

    topo_name = topology or cfg.topology
    n_nodes = n_nodes or 2 * cfg.n_pe
    g, feedback = build_bmvm_graph(np.asarray(lut), cfg)
    topo = make_topology(topo_name, n_nodes)
    place = resolve_placement(g, topo, placement, pod_of_node=pods,
                              serdes_cfg=serdes_cfg)
    plan = None
    if pods is not None:
        plan = cut(g, place, pods, serdes_cfg or QuasiSerdesConfig())
    ex = NoCExecutor(g, topo, placement=place, plan=plan, trace=tracer)
    v1 = np.asarray(v_bits).reshape(-1)               # single vector (n,)
    vw = np.asarray(kref.gf2_pack_vector(jnp.asarray(v1), cfg.k), np.uint32)
    f = cfg.fold
    inputs = {f"lut{i}.v": vw[i * f:(i + 1) * f] for i in range(cfg.n_pe)}
    outs, stats = ex.run_iterative(inputs, feedback, r, mode=mode)
    out_w = np.concatenate([np.asarray(outs[f"acc{i}.v"]) for i in range(cfg.n_pe)])
    return np.asarray(kref.gf2_unpack_vector(jnp.asarray(out_w), cfg.k)), stats


# ---------------------------------------------------------------------------
# SPMD (shard_map) realization — the production path
# ---------------------------------------------------------------------------

def iterate_spmd(lut: jax.Array, v_bits: jax.Array, cfg: BMVMConfig, r: int,
                 mesh=None, topology: str = "fattree"):
    """Distribute PEs over mesh devices; route via the topology schedule.

    lut (C, P, R) sharded over PEs on axis 0; v words likewise.  Each round:
    local lookup (C_loc rows of all R words) -> all-to-all (each node keeps
    its R_loc words from everyone) -> XOR-reduce."""
    from jax.sharding import Mesh, PartitionSpec as P_

    topo = make_topology(topology, (mesh.devices.size if mesh else jax.device_count()))
    axes = topology_axes(topo)
    if mesh is None:
        devs = np.array(jax.devices()[: topo.n_nodes]).reshape([s for _, s in axes])
        mesh = Mesh(devs, [a for a, _ in axes])
    n_nodes = topo.n_nodes
    a2a = all_to_all_for(topo)
    C, P2k, R = lut.shape
    assert C % n_nodes == 0 and R % n_nodes == 0
    r_loc = R // n_nodes
    vw = kref.gf2_pack_vector(v_bits, cfg.k).astype(jnp.uint32)   # (M, C)
    M = vw.shape[0]
    mesh_axes = tuple(a for a, _ in axes)
    lspec = P_(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0], None, None)
    vspec = P_(None, mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])

    def local(lut_loc, vw_loc):
        # vw_loc: (M, C_loc) this node's sub-vector words
        def body(vw_l, _):
            looked = jax.vmap(
                lambda vrow: jax.vmap(lambda lc, vv: lc[vv, :])(lut_loc, vrow)
            )(vw_l)                                             # (M, C_loc, R)
            part = looked[:, 0]
            for c in range(1, looked.shape[1]):
                part = jnp.bitwise_xor(part, looked[:, c])      # (M, R) local partial
            # packetize per destination node: dest j gets words [j*r_loc:(j+1)*r_loc]
            pkts = part.reshape(M, n_nodes, r_loc).swapaxes(0, 1)  # (n, M, r_loc)
            rcv = a2a(pkts)                                      # (n, M, r_loc)
            acc = rcv[0]
            for s in range(1, n_nodes):
                acc = jnp.bitwise_xor(acc, rcv[s])               # (M, r_loc) = my words
            return acc, None

        acc, _ = jax.lax.scan(body, vw_loc, None, length=1)
        return acc

    @jax.jit
    def run(lut_, vw_):
        def fn(lut_loc, vw_l):
            out = vw_l
            for _ in range(r):
                out = local(lut_loc, out)
            return out
        sm = shard_map(fn, mesh=mesh, in_specs=(lspec, vspec),
                       out_specs=vspec, check_vma=False)
        return sm(lut_, vw_)

    out_w = run(lut, vw)
    return kref.gf2_unpack_vector(out_w.astype(jnp.uint32), cfg.k)
