"""Case study II: particle-filter object tracking (paper §V).

SIS particle filter over synthetic video: reference histogram from frame 1,
then per frame — sample N particles around the previous estimate, compute
distance-weighted candidate histograms + Bhattacharyya weights (the paper's
Fig. 11 PE, here the fused Pallas histogram kernel), and a weighted-mean
center update (the paper's Node-0 root PE, Fig. 12).

Unlike LDPC this is *not* naturally message-passing — the point of the case
study — so phase-1 restructures it: particle batches become PEs, the root
orchestrates.  ``track_on_noc`` places exactly that graph on a NoC.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..core import (NoCExecutor, PE, Port, TaskGraph, cut, make_topology,
                    resolve_placement)
from ..kernels import ops as kops
from ..kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class PFConfig:
    img: int = 64           # square frames
    roi: int = 16           # square region of interest
    n_bins: int = 16
    n_particles: int = 64
    sigma_motion: float = 3.0
    sigma_bc: float = 0.1
    seed: int = 0


def synth_video(cfg: PFConfig, n_frames: int, rng) -> tuple[np.ndarray, np.ndarray]:
    """Moving bright blob on noise.  Returns (frames (F,H,W), centers (F,2))."""
    H = W = cfg.img
    centers = np.zeros((n_frames, 2))
    c = np.array([H / 2, W / 2])
    vel = rng.normal(0, 1.2, 2)
    frames = np.zeros((n_frames, H, W), np.float32)
    yy, xx = np.mgrid[0:H, 0:W]
    for f in range(n_frames):
        vel = 0.9 * vel + rng.normal(0, 0.4, 2)
        c = np.clip(c + vel, cfg.roi, cfg.img - cfg.roi - 1)
        centers[f] = c
        blob = np.exp(-(((yy - c[0]) ** 2 + (xx - c[1]) ** 2) / (2 * (cfg.roi / 3) ** 2)))
        frames[f] = 0.75 * blob + 0.25 * rng.uniform(0, 1, (H, W))
    return frames, centers


def _roi_bins(frame: jax.Array, centers: jax.Array, cfg: PFConfig) -> jax.Array:
    """Extract per-particle ROI pixel bin indices.  centers: (N,2) float."""
    r = cfg.roi

    def one(c):
        y = jnp.clip(c[0].astype(jnp.int32) - r // 2, 0, cfg.img - r)
        x = jnp.clip(c[1].astype(jnp.int32) - r // 2, 0, cfg.img - r)
        patch = jax.lax.dynamic_slice(frame, (y, x), (r, r))
        return jnp.clip((patch * cfg.n_bins).astype(jnp.int32), 0, cfg.n_bins - 1)

    return jax.vmap(one)(centers).reshape(centers.shape[0], r * r)


def distance_weights(cfg: PFConfig) -> jax.Array:
    """Epanechnikov kernel over the ROI (the paper's 'distance weighted')."""
    r = cfg.roi
    yy, xx = jnp.mgrid[0:r, 0:r]
    d2 = ((yy - r / 2 + 0.5) ** 2 + (xx - r / 2 + 0.5) ** 2) / ((r / 2) ** 2)
    return jnp.maximum(1 - d2, 0).astype(jnp.float32).reshape(-1)


def reference_histogram(frame: jax.Array, center: jax.Array, cfg: PFConfig) -> jax.Array:
    bins = _roi_bins(frame, center[None], cfg)
    w = distance_weights(cfg)
    h = kref.weighted_histogram(bins, w, cfg.n_bins)
    return h[0]


def step(frame: jax.Array, prev_center: jax.Array, ref_hist: jax.Array,
         cfg: PFConfig, key, use_kernel: bool = True):
    """One SIS update.  Returns (new_center, particle weights, particles)."""
    noise = jax.random.normal(key, (cfg.n_particles, 2)) * cfg.sigma_motion
    parts = prev_center[None, :] + noise
    parts = jnp.clip(parts, cfg.roi // 2, cfg.img - cfg.roi // 2 - 1)
    bins = _roi_bins(frame, parts, cfg)
    dw = distance_weights(cfg)
    _, bc = kops.particle_histogram(bins, dw, ref_hist, n_bins=cfg.n_bins,
                                    use_kernel=use_kernel)
    w = jnp.exp((bc - 1.0) / (cfg.sigma_bc ** 2))
    w = w / jnp.maximum(w.sum(), 1e-12)
    new_center = (w[:, None] * parts).sum(0)
    return new_center, w, parts


def track(frames: np.ndarray, cfg: PFConfig, use_kernel: bool = True) -> np.ndarray:
    """Full tracking run; returns estimated centers (F, 2)."""
    key = jax.random.key(cfg.seed)
    frames_j = jnp.asarray(frames)
    # initialize on the true blob via intensity argmax of frame 0
    f0 = frames_j[0]
    c0 = jnp.stack(jnp.unravel_index(jnp.argmax(f0), f0.shape)).astype(jnp.float32)
    ref = reference_histogram(f0, c0, cfg)
    centers = [np.asarray(c0)]
    c = c0
    for f in range(1, frames.shape[0]):
        key, k = jax.random.split(key)
        c, _, _ = step(frames_j[f], c, ref, cfg, k, use_kernel)
        centers.append(np.asarray(c))
    return np.stack(centers)


# ---------------------------------------------------------------------------
# NoC realization (paper Figs. 10 & 12): particle-group PEs + root PE
# ---------------------------------------------------------------------------

def build_pf_graph(cfg: PFConfig, n_pe: int) -> TaskGraph:
    assert cfg.n_particles % n_pe == 0
    per = cfg.n_particles // n_pe
    g = TaskGraph("particle_filter")
    r2 = cfg.roi * cfg.roi

    def pe_fn(**kw):
        bins, ref = kw["bins"].astype(jnp.int32), kw["ref"]
        parts = kw["parts"]
        dw = distance_weights(cfg)
        hist = kref.weighted_histogram(bins, dw, cfg.n_bins)
        bc = kref.bhattacharyya(hist, ref)
        w = jnp.exp((bc - 1.0) / (cfg.sigma_bc ** 2))
        return {"wsum": w.sum()[None], "wc": (w[:, None] * parts).sum(0)}

    def root_fn(**kw):
        wsum = sum(kw[f"wsum{i}"] for i in range(n_pe))
        wc = sum(kw[f"wc{i}"] for i in range(n_pe))
        return {"center": wc / jnp.maximum(wsum, 1e-12)}

    for i in range(n_pe):
        g.add(PE(f"pe{i}", pe_fn,
                 (Port("bins", (per, r2), np.int32), Port("ref", (cfg.n_bins,)),
                  Port("parts", (per, 2))),
                 (Port("wsum", (1,)), Port("wc", (2,)))))
    g.add(PE("root", root_fn,
             tuple(Port(f"wsum{i}", (1,)) for i in range(n_pe))
             + tuple(Port(f"wc{i}", (2,)) for i in range(n_pe)),
             (Port("center", (2,)),)))
    for i in range(n_pe):
        g.connect(f"pe{i}.wsum", f"root.wsum{i}")
        g.connect(f"pe{i}.wc", f"root.wc{i}")
    return g


def track_on_noc(frames: np.ndarray, cfg: PFConfig, n_pe: int = 4,
                 topology: str = "mesh", n_nodes: int = 8,
                 placement="rr", mode: str = "sim",
                 pods: Optional[list[int]] = None, serdes_cfg=None,
                 tracer=None):
    """Paper-faithful NoC execution; returns (centers, total NoCStats).

    ``placement``: 'rr' | 'greedy' | 'opt' or an explicit PE→node mapping.
    ``mode``: any `NoCExecutor.run` mode — ``"spmd"`` routes each frame's
    messages over a real device mesh (needs n_nodes devices).  ``pods``
    (node→pod) runs the tracker partitioned across chips: cut links go
    through quasi-SERDES bridges (``serdes_cfg``) with identical tracks and
    ``bridge_*`` counters in the stats.  ``tracer``: a
    `repro.telemetry.Tracer` recording all frames on one timeline."""
    from ..core.serdes import QuasiSerdesConfig

    g = build_pf_graph(cfg, n_pe)
    topo = make_topology(topology, n_nodes)
    place = resolve_placement(g, topo, placement, pod_of_node=pods,
                              serdes_cfg=serdes_cfg)
    plan = None
    if pods is not None:
        plan = cut(g, place, pods, serdes_cfg or QuasiSerdesConfig())
    ex = NoCExecutor(g, topo, placement=place, plan=plan, trace=tracer)
    key = jax.random.key(cfg.seed)
    frames_j = jnp.asarray(frames)
    f0 = frames_j[0]
    c0 = jnp.stack(jnp.unravel_index(jnp.argmax(f0), f0.shape)).astype(jnp.float32)
    ref = reference_histogram(f0, c0, cfg)
    per = cfg.n_particles // n_pe
    centers = [np.asarray(c0)]
    c = c0
    total_stats = None
    for f in range(1, frames.shape[0]):
        key, k = jax.random.split(key)
        noise = jax.random.normal(k, (cfg.n_particles, 2)) * cfg.sigma_motion
        parts = jnp.clip(c[None] + noise, cfg.roi // 2, cfg.img - cfg.roi // 2 - 1)
        bins = _roi_bins(frames_j[f], parts, cfg)
        inputs = {}
        for i in range(n_pe):
            inputs[f"pe{i}.bins"] = bins[i * per:(i + 1) * per]
            inputs[f"pe{i}.ref"] = ref
            inputs[f"pe{i}.parts"] = parts[i * per:(i + 1) * per]
        outs, stats = ex.run(inputs, mode=mode)
        c = jnp.asarray(outs["root.center"])
        centers.append(np.asarray(c))
        if total_stats is None:
            total_stats = stats
        else:
            total_stats.add(stats)   # peak counters merge by max, flows sum
    return np.stack(centers), total_stats
