"""Case study I: LDPC decoding, min-sum algorithm (paper §IV).

Two realizations, exactly as the paper structures it:

* **TaskGraph** — one PE per bit/check node (the paper's N=7 projective-
  geometry code = the Fano plane PG(2,2), 7+7 nodes of degree 3), wrapped
  and placed on a 4×4 mesh NoC (Fig. 9), including the 2-FPGA partition cut
  (the dotted arc).
* **Vectorized edge arrays** — the scalable form: all check updates are one
  (M, dc) block through the min-sum Pallas kernel, bit updates are one
  segment-sum; node↔node message motion is a static edge permutation (what
  the NoC routes).  This is what the LM-scale framework would actually run.

Both are property-tested equal, and decode correctly over an AWGN channel.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..core import (NoCExecutor, PE, Port, TaskGraph, cut, make_topology,
                    resolve_placement)
from ..kernels import ops as kops
from ..kernels import ref as kref


def fano_plane_H() -> np.ndarray:
    """PG(2,2) point-line incidence: the paper's N=7, degree-3 LDPC code."""
    lines = [(0, 1, 2), (0, 3, 4), (0, 5, 6), (1, 3, 5), (1, 4, 6), (2, 3, 6), (2, 4, 5)]
    H = np.zeros((7, 7), np.int8)
    for c, pts in enumerate(lines):
        H[c, list(pts)] = 1
    return H


def pg_ldpc_H(m: int = 7, copies: int = 1) -> np.ndarray:
    """Block-diagonal replication of the Fano code (scaling knob)."""
    H = fano_plane_H()
    if copies == 1:
        return H
    out = np.zeros((7 * copies, 7 * copies), np.int8)
    for i in range(copies):
        out[7 * i:7 * i + 7, 7 * i:7 * i + 7] = H
    return out


@dataclasses.dataclass
class EdgeIndex:
    """Static routing tables for a regular LDPC code (dc, dv constant)."""

    H: np.ndarray
    check_edges: np.ndarray   # (M, dc) edge ids in check-major order
    bit_edges: np.ndarray     # (N, dv) edge ids in bit-major order
    edge_bit: np.ndarray      # (E,) bit index of edge e (check-major)
    n_edges: int


def build_edge_index(H: np.ndarray) -> EdgeIndex:
    M, N = H.shape
    cs, bs = np.nonzero(H)
    E = len(cs)
    dc = E // M
    check_edges = np.arange(E).reshape(M, dc)           # check-major enumeration
    bit_edges = np.zeros((N, (H.sum(0)).max()), np.int64)
    for b in range(N):
        bit_edges[b] = np.nonzero(bs == b)[0]
    return EdgeIndex(H, check_edges, bit_edges, bs, E)


def decode_minsum(idx: EdgeIndex, llr: jax.Array, n_iters: int,
                  use_kernel: bool = True) -> tuple[jax.Array, jax.Array]:
    """llr: (..., N) channel LLRs -> (decoded bits (..., N), posterior)."""
    M, dc = idx.check_edges.shape
    ce = jnp.asarray(idx.check_edges)
    be = jnp.asarray(idx.bit_edges)
    eb = jnp.asarray(idx.edge_bit)

    def one(llr1):
        u = llr1[eb]                                       # bit->check messages (E,)

        def body(u, _):
            uc = u[ce.reshape(-1)].reshape(M, dc)          # Data Collector gather
            vc = kops.minsum_check(uc, use_kernel=use_kernel)
            v = vc.reshape(-1)                             # check->bit on edges
            vb = v[be]                                     # (N, dv)
            total = llr1 + vb.sum(-1)                      # bit node (Listing 3)
            u_bit = total[:, None] - vb                    # exclude self
            u_new = jnp.zeros_like(u).at[be.reshape(-1)].set(u_bit.reshape(-1))
            return u_new, total

        _, totals = jax.lax.scan(body, u, None, length=n_iters)
        post = totals[-1]
        return (post < 0).astype(jnp.int8), post

    flat = llr.reshape(-1, llr.shape[-1])
    bits, post = jax.vmap(one)(flat)
    return bits.reshape(llr.shape), post.reshape(llr.shape)


# ---------------------------------------------------------------------------
# TaskGraph realization (paper Fig. 9)
# ---------------------------------------------------------------------------

def build_ldpc_graph(H: np.ndarray) -> tuple[TaskGraph, list[tuple[str, str]]]:
    """One PE per node; returns (graph, feedback wiring for run_iterative)."""
    M, N = H.shape
    g = TaskGraph("ldpc_minsum")
    deg_c = int(H.sum(1).max())
    deg_v = int(H.sum(0).max())

    def check_fn(**u):
        arr = jnp.stack([u[f"u{i}"] for i in range(deg_c)])[None, :, 0]
        v = kref.minsum_check(arr)[0]
        return {f"v{i}": v[i:i + 1] for i in range(deg_c)}

    def bit_fn(**kw):
        u0 = kw["u0"]
        vs = jnp.stack([kw[f"v{i}"] for i in range(deg_v)])[:, 0]
        total = u0 + vs.sum()
        out = {f"u{i}": total - vs[i:i + 1] for i in range(deg_v)}
        out["post"] = total
        return out

    for c in range(M):
        g.add(PE(f"chk{c}", check_fn,
                 tuple(Port(f"u{i}", (1,)) for i in range(deg_c)),
                 tuple(Port(f"v{i}", (1,)) for i in range(deg_c))))
    for b in range(N):
        g.add(PE(f"bit{b}", bit_fn,
                 (Port("u0", (1,)),) + tuple(Port(f"v{i}", (1,)) for i in range(deg_v)),
                 tuple(Port(f"u{i}", (1,)) for i in range(deg_v)) + (Port("post", (1,)),)))
    # wire: edge (c, b) — check input slot j_c, bit input slot j_b
    feedback = []
    for c in range(M):
        for j_c, b in enumerate(np.nonzero(H[c])[0]):
            j_b = list(np.nonzero(H[:, b])[0]).index(c)
            g.connect(f"chk{c}.v{j_c}", f"bit{b}.v{j_b}")
            feedback.append((f"bit{b}.u{j_b}", f"chk{c}.u{j_c}"))
    return g, feedback


def decode_on_noc(H: np.ndarray, llr: np.ndarray, n_iters: int,
                  topology: str = "mesh", n_nodes: int = 16,
                  pods: Optional[list[int]] = None,
                  placement="rr", mode: str = "sim", serdes_cfg=None,
                  tracer=None):
    """Full paper flow: graph -> placement -> (optional 2-pod cut) -> sim.

    ``placement``: 'rr' | 'greedy' | 'opt' (annealing search, cut-aware when
    ``pods`` is given) or an explicit PE→node mapping.  Initial check inputs
    are the channel LLRs of the connected bits (the standard initialization
    u_ij^{(0)} = llr_j).  ``mode``: any `NoCExecutor.run` mode — ``"spmd"``
    moves the messages over a real device mesh (needs n_nodes devices).
    With ``pods`` the decode runs *partitioned*: cut links go through
    quasi-SERDES bridge endpoints (``serdes_cfg`` — framing/lanes of the
    inter-chip links), bit-identically to the unpartitioned run, and the
    returned NoCStats carry the ``bridge_*`` counters.  ``tracer``: a
    `repro.telemetry.Tracer` recording the decode's event timeline."""
    from ..core.serdes import QuasiSerdesConfig

    g, feedback = build_ldpc_graph(H)
    topo = make_topology(topology, n_nodes)
    placement = resolve_placement(g, topo, placement, pod_of_node=pods,
                                  serdes_cfg=serdes_cfg)
    plan = None
    if pods is not None:
        plan = cut(g, placement, pods, serdes_cfg or QuasiSerdesConfig())
    ex = NoCExecutor(g, topo, placement=placement, plan=plan, trace=tracer)
    M, N = H.shape
    inputs = {}
    for b in range(N):
        inputs[f"bit{b}.u0"] = jnp.asarray(llr[b:b + 1], jnp.float32)
    for c in range(M):
        for j_c, b in enumerate(np.nonzero(H[c])[0]):
            inputs[f"chk{c}.u{j_c}"] = jnp.asarray(llr[b:b + 1], jnp.float32)
    outs, stats = ex.run_iterative(inputs, feedback, n_iters, mode=mode)
    post = np.array([float(outs[f"bit{b}.post"][0]) for b in range(N)])
    return (post < 0).astype(np.int8), post, stats


# ---------------------------------------------------------------------------
# channel simulation
# ---------------------------------------------------------------------------

def awgn_llr(bits: np.ndarray, snr_db: float, rng) -> np.ndarray:
    """BPSK over AWGN -> channel LLRs."""
    x = 1.0 - 2.0 * bits.astype(np.float64)
    sigma = np.sqrt(0.5 * 10 ** (-snr_db / 10))
    y = x + sigma * rng.normal(size=x.shape)
    return (2.0 * y / (sigma ** 2)).astype(np.float32)
