"""Config system: ModelConfig covers all assigned architectures; ShapeConfig
covers the assigned input-shape sets; input_specs() builds the
ShapeDtypeStruct stand-ins the dry-run lowers against (no allocation).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# (mixer, ffn) kinds per sub-layer; a model is pattern × n_periods
MIXERS = ("attn", "mla", "mamba", "mlstm", "slstm")
FFNS = ("mlp", "moe", "none")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | xlstm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: tuple[tuple[str, str], ...] = (("attn", "mlp"),)
    head_dim: int = 0                # 0 -> d_model // n_heads
    # attention
    qk_norm: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    attn_impl: str = "blocked"       # naive | blocked | flash
    attn_compute_dtype: str = "f32"  # f32 (baseline) | bf16 (opt: f32 accum)
    mla_absorb: bool = False         # MLA absorbed formulation (opt)
    pad_vocab: bool = False          # pad V to /256 so embed/head shard (opt)
    bkv: int = 512
    logit_softcap: float = 0.0
    # mlp
    act: str = "silu"                # silu | gelu (gelu => GeGLU when gated)
    gated_mlp: bool = True           # False: plain 2-layer MLP (whisper)
    # embeddings
    tie_embeddings: bool = False
    embed_scale: float = 1.0
    pos_embed: str = "rope"          # rope | sinusoidal
    norm_eps: float = 1e-6
    # moe
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_impl: str = "gather"         # gather | noc | dense
    moe_topology: str = "fattree"    # fattree | ring | mesh2d | torus2d
    capacity_factor: float = 1.25
    # >0: CONNECT flit-buffer-depth capacity knob — each (src, expert)
    # dispatch FIFO holds this many token slots and capacity_factor is
    # DERIVED from it (models.moe.dispatch_capacity); 0: use capacity_factor
    moe_flit_buffer_depth: int = 0
    aux_weight: float = 0.01
    # mamba
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_chunk: int = 256
    # xlstm
    xlstm_proj_factor: float = 2.0
    xlstm_chunk: int = 128
    # encoder (enc-dec) / frontend (audio, vlm)
    n_enc_layers: int = 0
    enc_seq: int = 0                 # whisper: 1500 frames
    d_frontend: int = 0              # mel bins / ViT width
    n_patches: int = 0               # vlm prefix length
    # compute
    dtype: str = "bfloat16"
    serve_param_dtype: str = "float32"   # bfloat16 => serving reads bf16 params
    remat: bool = True
    analysis_unroll: bool = False    # roofline analysis: unroll inner seq scans
    seq_shard_kv: bool = False       # long-context: shard KV/state seq over 'data'

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab // 256) * 256 if self.pad_vocab else self.vocab

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (self.name, self.n_layers, len(self.pattern))
        return self.n_layers // len(self.pattern)

    @property
    def cdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- analytics -----------------------------------------------------------
    def param_count(self) -> int:
        from ..models.transformer import abstract_params
        from ..models.layers import count_params
        return count_params(abstract_params(self))

    def active_param_count(self) -> int:
        """MoE: params touched per token (for MODEL_FLOPS = 6·N_active·D)."""
        if not self.n_experts:
            return self.param_count()
        from ..models.transformer import abstract_params
        from ..models.layers import is_spec
        tree = abstract_params(self)
        total = 0
        for path, spec in jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_spec)[0]:
            n = 1
            for s in spec.shape:
                n *= s
            if self.n_experts in spec.shape and "experts" in spec.axes:
                n = n // self.n_experts * self.top_k
            total += n
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def long_context_ok(cfg: ModelConfig) -> bool:
    """long_500k runs for SSM/hybrid archs; skipped for pure full-attention."""
    mixers = {m for m, _ in cfg.pattern}
    return bool(mixers & {"mamba", "mlstm", "slstm"})


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    if shape.name == "long_500k" and not long_context_ok(cfg):
        return False, "pure full-attention arch: 500k dense-KV decode out of regime (per spec)"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    def tok(s):
        return jax.ShapeDtypeStruct(s, jnp.int32)
    if shape.kind == "train":
        specs = {"tokens": tok((B, S)), "labels": tok((B, S))}
    elif shape.kind == "prefill":
        specs = {"tokens": tok((B, S))}
    else:  # decode: one new token against a seq_len cache
        specs = {"tokens": tok((B, 1))}
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_seq, cfg.d_frontend), cfg.cdtype)
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_frontend), cfg.cdtype)
    return specs


# registry filled by the per-arch modules
REGISTRY: dict[str, ModelConfig] = {}
SMOKE_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig, smoke: ModelConfig) -> ModelConfig:
    REGISTRY[cfg.name] = cfg
    SMOKE_REGISTRY[cfg.name] = smoke
    return cfg


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    from . import ALL_ARCHS  # noqa: F401  (import side effect: fill registry)
    reg = SMOKE_REGISTRY if smoke else REGISTRY
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; have {sorted(reg)}")
    return reg[name]
