"""Gemma-7B  [dense]  28L d_model=3072 16H (MHA kv=16) d_ff=24576
vocab=256000 — GeGLU, head_dim=256, embeddings scaled by sqrt(d), tied.
(MQA is on the 2B sibling; 7B is MHA.)  [arXiv:2403.08295; hf]
"""
import math

from .base import ModelConfig, register

FULL = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab=256000,
    act="gelu",                      # gated GeLU = GeGLU
    tie_embeddings=True,
    embed_scale=math.sqrt(3072.0),
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab=256, dtype="float32", remat=False, attn_impl="naive",
    embed_scale=8.0,
)

register(FULL, SMOKE)
