"""Whisper-large-v3  [audio]  enc-dec, 32+32L d_model=1280 20H (kv=20)
d_ff=5120 vocab=51866.  Conv frontend STUBBED per assignment: input_specs
provide precomputed (B, 1500, 128) mel-frame embeddings; the in-model
frontend is the projection to d_model + sinusoidal positions.  Plain (ungated)
GeLU MLPs, absolute positions (no rope).  [arXiv:2212.04356; unverified]
"""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    n_enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    act="gelu",
    gated_mlp=False,
    use_rope=False,
    pos_embed="sinusoidal",
    enc_seq=1500,
    d_frontend=128,
    tie_embeddings=True,
)

SMOKE = FULL.replace(
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab=256, enc_seq=24, d_frontend=8,
    dtype="float32", remat=False, attn_impl="naive",
)

register(FULL, SMOKE)
