"""InternVL2-1B  [vlm]  LM backbone (Qwen2-0.5B): 24L d_model=896 14H
(GQA kv=2) d_ff=4864 vocab=151655.  InternViT frontend STUBBED per
assignment: input_specs provide precomputed (B, 256, 1024) patch embeddings;
the in-model frontend is the mlp projector to d_model.
[arXiv:2404.16821; hf]
"""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab=151655,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    n_patches=256,
    d_frontend=1024,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab=256, n_patches=8, d_frontend=16, dtype="float32", remat=False,
    attn_impl="naive",
)

register(FULL, SMOKE)
