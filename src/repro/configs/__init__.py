"""Assigned architecture configs (public-literature parameterizations).

Importing this package registers every arch in base.REGISTRY (full config)
and base.SMOKE_REGISTRY (reduced same-family config for CPU smoke tests).
"""
from .base import (REGISTRY, SHAPES, SMOKE_REGISTRY, ModelConfig, ShapeConfig,
                   cell_supported, get_config, input_specs, long_context_ok, register)

from . import (whisper_large_v3, xlstm_350m, qwen3_moe_235b_a22b, phi35_moe_42b,
               jamba_v01_52b, minicpm3_4b, llama32_1b, gemma_7b, command_r_35b,
               internvl2_1b)

ALL_ARCHS = tuple(sorted(REGISTRY))
