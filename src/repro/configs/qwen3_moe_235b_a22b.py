"""Qwen3-MoE-235B-A22B  [moe]  94L d_model=4096 64H (GQA kv=4) vocab=151936,
MoE 128 experts top-8, d_ff_expert=1536·8? — per assignment d_ff=1536 is the
per-expert FFN width (moe_intermediate_size). QK-norm, head_dim=128,
rope_theta=1e6.  [hf:Qwen/Qwen3-30B-A3B family scaling; hf]

This is the flagship cell for the paper's technique: 128 expert PEs on the
packet-switched network, top-8 routed token packets.
"""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab=151936,
    pattern=(("attn", "moe"),),
    qk_norm=True,
    rope_theta=1_000_000.0,
    n_experts=128,
    top_k=8,
    d_ff_expert=1536,
    moe_impl="gather",
    # flagship NoC mapping when moe_impl="noc": 128 expert PEs on a 2D torus
    moe_topology="torus2d",
    tie_embeddings=False,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, vocab=256,
    n_experts=8, top_k=2, d_ff_expert=32, dtype="float32", remat=False,
    attn_impl="naive", moe_impl="dense",
)

register(FULL, SMOKE)
