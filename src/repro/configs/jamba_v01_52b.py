"""Jamba-v0.1-52B  [hybrid]  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2.  Mamba+attention 1:7 interleave
(attn_layer_period=8 offset 4), MoE every 2nd layer (offset 1).
No positional embeddings (the SSM layers carry position).  [arXiv:2403.19887; hf]
"""
from .base import ModelConfig, register

# one period = 8 layers: attn at index 4, MoE at odd indices
_PATTERN = tuple(
    ("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "mlp")
    for i in range(8)
)

FULL = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=65536,
    pattern=_PATTERN,
    use_rope=False,
    n_experts=16,
    top_k=2,
    d_ff_expert=14336,
    moe_impl="gather",
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
)

SMOKE = FULL.replace(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab=256, n_experts=4, top_k=2, d_ff_expert=96, dtype="float32",
    remat=False, attn_impl="naive", moe_impl="dense", mamba_chunk=16,
)

register(FULL, SMOKE)
