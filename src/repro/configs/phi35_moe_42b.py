"""Phi-3.5-MoE-42B-A6.6B  [moe]  32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab=32064,
    pattern=(("attn", "moe"),),
    rope_theta=10_000.0,
    n_experts=16,
    top_k=2,
    d_ff_expert=6400,
    moe_impl="gather",
    moe_topology="mesh2d",   # NoC mapping when moe_impl="noc"
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, vocab=256,
    n_experts=4, top_k=2, d_ff_expert=48, dtype="float32", remat=False,
    attn_impl="naive", moe_impl="dense",
)

register(FULL, SMOKE)
