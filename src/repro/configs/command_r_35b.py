"""Command-R-35B  [dense]  40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 — GQA, no biases, tied embeddings, rope_theta=8e6.
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab=256000,
    rope_theta=8_000_000.0,
    tie_embeddings=True,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab=256, dtype="float32", remat=False, attn_impl="naive",
)

register(FULL, SMOKE)
