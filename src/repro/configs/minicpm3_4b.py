"""MiniCPM3-4B  [dense]  62L d_model=2560 40H d_ff=6400 vocab=73448 — MLA
(multi-head latent attention: q_lora 768, kv_lora 256, nope 64, rope 32,
v 64).  [hf:openbmb/MiniCPM3-4B; hf]
"""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    vocab=73448,
    pattern=(("mla", "mlp"),),
    tie_embeddings=True,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab=256, dtype="float32", remat=False, attn_impl="naive",
)

register(FULL, SMOKE)
