"""Llama-3.2-1B  [dense]  16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256, head_dim=64, rope_theta=500000, tied embeddings.
[hf:meta-llama/Llama-3.2-1B; unverified]
"""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab=128256,
    rope_theta=500_000.0,
    tie_embeddings=True,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab=256, dtype="float32", remat=False, attn_impl="naive",
)

register(FULL, SMOKE)
