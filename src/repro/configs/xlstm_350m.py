"""xLSTM-350M  [ssm]  24L d_model=1024 4H vocab=50304, sLSTM + mLSTM blocks
(d_ff=0: the blocks carry their own projections; sLSTM block keeps the 4/3
GeLU FFN per the paper's block design).  Pattern: one sLSTM per 6 layers.
[arXiv:2405.04517; unverified]
"""
from .base import ModelConfig, register

_PATTERN = tuple(
    ("slstm" if i == 5 else "mlstm", "none") for i in range(6)
)

FULL = ModelConfig(
    name="xlstm-350m",
    family="xlstm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    pattern=_PATTERN,
    use_rope=False,
    tie_embeddings=True,
    xlstm_proj_factor=2.0,
    xlstm_chunk=128,
)

SMOKE = FULL.replace(
    n_layers=6, d_model=64, n_heads=4, vocab=256, dtype="float32",
    remat=False, xlstm_chunk=16,
)

register(FULL, SMOKE)
