"""AdamW implemented natively (no optax dependency), pjit-friendly.

Optimizer state mirrors the param tree (same sharding → ZeRO-style: because
m/v inherit the param PartitionSpecs and params are sharded over 'model',
optimizer memory scales down with TP; optionally shard replicated leaves over
'data' via ``zero_spec``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params) -> dict:
    def zeros(p):
        return jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def adamw_update(params, grads, state: dict, cfg: AdamWConfig,
                 lr: Optional[jax.Array] = None):
    """-> (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr_t = cfg.lr if lr is None else lr
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mh = m_new / b1c
        vh = v_new / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
