"""``python -m repro.analysis`` — alias for the lint CLI (see `lint.main`)."""
import sys

from .lint import main

sys.exit(main())
