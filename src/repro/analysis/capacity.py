"""Static capacity/occupancy bounds for compiled NoC executions.

Derived from the compiled wave layouts alone (no flit is moved), these bounds
bracket what the cycle-accurate simulators later measure:

* **exact** quantities — total flits, payload bytes, and per-wave
  ``link_flits`` (each flit crosses exactly its route's hop count of links in
  ``mode="buffered"``, so ``link_bytes == link_flits × flit_wire_bytes``
  bit-for-bit), and the bridge counters (`interchip.bridge_program_stats` is
  exact against the bridged simulator by construction);
* **sound upper bounds** — peak input-FIFO occupancy (a ``(link, vc)``
  channel can never hold more flits than ``min(buffer_depth, its total
  load)``) and peak per-cycle link crossings (at most one flit per distinct
  loaded link per cycle).  The property suite asserts measured `NoCStats`
  high-water marks never exceed these and that the exact parts agree
  bit-for-bit.

`check_traffic` closes the loop for the synthetic-traffic workloads: offered
``injection_rate`` is compared against the analytic `switch.saturation_rate`
for the pattern's `traffic_matrix` (NOC006), and degenerate topologies with
no destinations are rejected (NOC014).
"""
from __future__ import annotations

import dataclasses

from ..core.topology import Topology
from .cdg import route_channels
from .diagnostics import Diagnostic, diag


@dataclasses.dataclass
class CapacityReport:
    """Static bounds for one executor's compiled program (single input set).

    ``flits``/``payload_bytes``/``link_flits``/``link_bytes``/``bridge_*``
    are exact for one ``run``; ``peak_queue`` and ``peak_link_flits`` are
    sound upper bounds on the matching `NoCStats` high-water marks."""

    flits: int = 0
    payload_bytes: int = 0
    link_flits: int = 0
    link_bytes: int = 0
    peak_queue: int = 0
    peak_link_flits: int = 0
    bridge_beats: int = 0
    bridge_wire_bytes: int = 0
    bridge_stall_rounds: int = 0
    bridge_peak_fifo: int = 0
    diagnostics: list = dataclasses.field(default_factory=list)


def wave_channel_loads(topo: Topology, pairs, flit_bytes: int,
                       n_vcs: int) -> dict[tuple[int, int, int], int]:
    """Flits per (link, vc) channel for one wave's compiled pair layout."""
    loads: dict[tuple[int, int, int], int] = {}
    for s, d, nb in pairs:
        if nb <= 0:
            continue
        flits = -(-nb // flit_bytes)
        for ch in route_channels(topo, s, d, n_vcs):
            loads[ch] = loads.get(ch, 0) + flits
    return loads


def executor_bounds(ex) -> CapacityReport:
    """Static CapacityReport for a `NoCExecutor`'s compiled wave programs."""
    cfg = ex.cfg
    topo = ex.topo
    depth = cfg.switch_buffer_depth
    fb = cfg.flit_wire_bytes
    rep = CapacityReport()
    for w, prog in enumerate(ex.programs):
        rep.flits += prog.static.flits
        rep.payload_bytes += prog.static.payload_bytes
        if not prog.slots:
            continue
        try:
            loads = wave_channel_loads(topo, prog.pairs, fb, cfg.switch_vcs)
        except TypeError:      # topology without dimension-ordered routes
            continue
        if not loads:
            continue
        rep.link_flits += sum(loads.values())
        worst_ch = max(loads, key=loads.get)
        worst = loads[worst_ch]
        rep.peak_queue = max(rep.peak_queue, min(depth, worst))
        links_used = len({(u, v) for u, v, _ in loads})
        rep.peak_link_flits = max(rep.peak_link_flits, links_used)
        if worst >= depth:
            u, v, vc = worst_ch
            rep.diagnostics.append(diag(
                "NOC005", f"wave {w}: input FIFO ({u}->{v} vc{vc}) takes "
                          f"{worst} flits against depth {depth} — credit "
                          f"stalls predicted (correctness unaffected)",
                "NoCConfig.switch_buffer_depth"))
    rep.link_bytes = rep.link_flits * fb
    if ex.plan is not None:
        from ..core.interchip import bridge_program_stats

        bprog = ex._ensure_bridge()
        n = topo.n_nodes
        for prog in ex.programs:
            if not prog.slots or prog.buf_bytes == 0:
                continue
            b = bridge_program_stats(bprog, n * n * prog.buf_bytes)
            rep.bridge_beats += b.beats
            rep.bridge_wire_bytes += b.wire_bytes
            rep.bridge_stall_rounds += b.stall_rounds
            rep.bridge_peak_fifo = max(rep.bridge_peak_fifo, b.peak_fifo)
        if rep.bridge_peak_fifo >= cfg.bridge_fifo_depth:
            rep.diagnostics.append(diag(
                "NOC013", f"bridge FIFO peaks at {rep.bridge_peak_fifo} "
                          f"wire words against depth "
                          f"{cfg.bridge_fifo_depth} — back-pressure stall "
                          f"rounds predicted",
                "NoCConfig.bridge_fifo_depth"))
    return rep


def check_traffic(topo: Topology, tcfg,
                  n_vcs: int = 2) -> list[Diagnostic]:
    """NOC006/NOC014 diagnostics for a `traffic.TrafficConfig` on ``topo``."""
    from ..core.switch import saturation_rate
    from ..core.traffic import traffic_matrix

    where = f"TrafficConfig({tcfg.pattern})"
    n = topo.n_nodes
    diags: list[Diagnostic] = []
    if n < 2:
        diags.append(diag("NOC014", f"{topo.name} has {n} node(s): no "
                                    f"destination exists for injected "
                                    f"traffic", where))
        return diags
    if tcfg.pattern == "hotspot" and not 0 <= tcfg.hotspot < n:
        diags.append(diag("NOC014", f"hotspot node {tcfg.hotspot} outside "
                                    f"the {n}-node fabric", where))
        return diags
    sat = saturation_rate(topo, traffic_matrix(topo, tcfg), n_vcs)
    if tcfg.injection_rate > sat:
        diags.append(diag(
            "NOC006", f"offered load {tcfg.injection_rate:.3f} "
                      f"flits/cycle/node exceeds the analytic saturation "
                      f"rate {sat:.3f} for the {tcfg.pattern} pattern on "
                      f"{topo.name} n={n} — queues grow without bound in "
                      f"the open-loop regime", where))
    return diags


def predicted_peaks(topo: Topology, pairs, flit_bytes: int, n_vcs: int,
                    depth: int) -> tuple[int, int]:
    """(peak_queue, peak_link_flits) bounds for one raw pair layout —
    the standalone-workload analog of :func:`executor_bounds`."""
    loads = wave_channel_loads(topo, pairs, flit_bytes, n_vcs)
    if not loads:
        return 0, 0
    return (min(depth, max(loads.values())),
            len({(u, v) for u, v, _ in loads}))
