"""Static verification of compiled NoC artifacts — no flit is ever moved.

Everything the compilation pipeline emits (`routing.RouteProgram` line
schedules, `noc.NoCExecutor` wave layouts, `interchip.BridgedProgram` pod
projections, `switch.SwitchConfig`/`noc.NoCConfig` parameter sets) is checked
*before* execution:

* `cdg` — Dally–Seitz channel-dependency deadlock proofs over the switch's
  actual routing function, replacing the hand-written VC guard;
* `delivery` — exactly-once delivery/conservation proofs for compiled route
  programs, bridged pod projections, and wave scatter/gather layouts;
* `capacity` — exact flit/byte accounting plus sound peak-occupancy bounds
  against the simulators' ``NoCStats`` high-water marks, and traffic
  saturation checks;
* `lint` — config linters and :func:`verify_executor`, the composition that
  backs ``NoCExecutor(verify="strict"|"warn"|"off")`` and the
  ``python -m repro.analysis.lint`` CLI.

Error-code reference
--------------------
Codes are stable, append-only identifiers (see `diagnostics.CODES`); the
severity is fixed per code.  ``error`` means executing the artifact can
wedge, drop, or corrupt traffic; ``warning`` predicts degraded-but-correct
behavior.

========  ========  ====================================================
Code      Severity  Meaning
========  ========  ====================================================
NOC001    error     channel-dependency cycle: (topology, n_vcs) can
                    deadlock under wormhole switching
NOC002    error     invalid switch parameter (buffer depth / VC count)
NOC003    error     compiled route program violates exactly-once
                    delivery/conservation
NOC004    error     bridged program cut mismatch (cut hop without a
                    BridgeLink, or inconsistent pod tables)
NOC005    warning   switch input FIFO predicted to saturate (peak
                    occupancy reaches buffer depth)
NOC006    warning   offered traffic load exceeds the analytic
                    saturation rate
NOC007    error     invalid placement (unknown PE or node out of range)
NOC008    error     invalid pod cut (coverage, pod ids, or channel
                    classification)
NOC009    error     PE graph contract violation (shape/dtype mismatch,
                    double-written port, or dataflow cycle)
NOC010    warning   serdes framing mismatch (flit word and wire beat
                    sizes force padding on every crossing)
NOC011    warning   MoE dispatch config degrades (expert count not
                    divisible across ranks, or unusable knobs)
NOC012    error     invalid NoCConfig field (non-positive
                    width/depth/VC count)
NOC013    warning   bridge FIFO predicted to back-pressure (peak
                    occupancy reaches fifo_depth)
NOC014    error     traffic config unusable on this topology (no
                    destinations, or hotspot out of range)
========  ========  ====================================================
"""
from .capacity import (CapacityReport, check_traffic, executor_bounds,
                       predicted_peaks, wave_channel_loads)
from .cdg import (build_cdg, check_deadlock_freedom, deadlock_cycle,
                  find_graph_cycle, find_wait_cycle, format_channel_cycle,
                  route_channels)
from .delivery import (verify_bridged_program, verify_route_program,
                       verify_wave_layout)
from .diagnostics import (CODES, ERROR, WARNING, Diagnostic,
                          VerificationError, diag, errors,
                          format_diagnostics)
from .lint import (lint_graph, lint_model_config, lint_noc_config,
                   lint_placement, lint_plan, verify_executor)

__all__ = [
    "CODES", "ERROR", "WARNING", "CapacityReport", "Diagnostic",
    "VerificationError", "build_cdg", "check_deadlock_freedom",
    "check_traffic", "deadlock_cycle", "diag", "errors", "executor_bounds",
    "find_graph_cycle", "find_wait_cycle", "format_channel_cycle",
    "format_diagnostics", "lint_graph", "lint_model_config",
    "lint_noc_config", "lint_placement", "lint_plan", "predicted_peaks",
    "route_channels", "verify_bridged_program", "verify_executor",
    "verify_route_program", "verify_wave_layout", "wave_channel_loads",
]
