"""Dally–Seitz channel-dependency deadlock proofs for the wormhole switch.

A *channel* is one input VC FIFO of the buffered switch, identified by the
directed physical link it terminates plus the virtual channel:
``(u, v, vc)`` — the VC-``vc`` FIFO at router ``v`` fed by upstream ``u``.
Routing induces a dependency ``a -> b`` whenever some packet's route occupies
channel ``a`` and next requests channel ``b``: a flit parked in ``a`` can be
waiting on buffer space in ``b``.  The classic theorem (Dally & Seitz 1987):
wormhole routing is deadlock-free **iff** this channel dependency graph is
acyclic.

:func:`build_cdg` enumerates every ``dor_route`` of a topology (the switch's
routing function, including its dateline VC assignment) and collects the
dependency edges; :func:`deadlock_cycle` returns ``None`` as a *proof* of
deadlock freedom or a concrete channel cycle as the counterexample.  This
replaces the hand-written "wrapped topologies need 2 VCs" guard, which was
imprecise in both directions — e.g. a 2-node ring or 2×2 torus is provably
safe at one VC (each dimension's routes are single-hop, so no dependency
chain ever forms), while the cyclic cases now come with the actual cycle.

:func:`find_wait_cycle` is the runtime companion: given the wait-for map of a
wedged simulation (each occupied channel → the channel its head flit wants),
it names the culprit cycle for the ``DeadlockError`` message.
"""
from __future__ import annotations

import functools
from typing import Hashable, Mapping, Optional, Sequence

from ..core.topology import Topology
from .diagnostics import Diagnostic, diag

#: one input-VC FIFO: (upstream node, downstream node, virtual channel)
Channel = tuple[int, int, int]


def route_channels(topo: Topology, src: int, dst: int,
                   n_vcs: int) -> list[Channel]:
    """The channel sequence a (src, dst) packet occupies under dor_route."""
    from ..core.switch import dor_route

    route, vcs = dor_route(topo, src, dst, n_vcs)
    return [(route[i], route[i + 1], vcs[i]) for i in range(len(route) - 1)]


def build_cdg(topo: Topology, n_vcs: int) -> dict[Channel, set[Channel]]:
    """Channel dependency graph of every dor_route over ``topo``."""
    deps: dict[Channel, set[Channel]] = {}
    n = topo.n_nodes
    for s in range(n):
        for d in range(n):
            if s == d:
                continue
            chans = route_channels(topo, s, d, n_vcs)
            for c in chans:
                deps.setdefault(c, set())
            for a, b in zip(chans, chans[1:]):
                deps[a].add(b)
    return deps


def find_graph_cycle(deps: Mapping[Hashable, set]) -> Optional[list]:
    """First cycle of a directed graph (DFS), or None if acyclic."""
    WHITE, GRAY, BLACK = 0, 1, 2
    color = dict.fromkeys(deps, WHITE)
    for root in deps:
        if color[root] != WHITE:
            continue
        color[root] = GRAY
        path = [root]
        iters = [iter(sorted(deps[root]))]
        while path:
            nxt = next(iters[-1], None)
            if nxt is None:
                color[path.pop()] = BLACK
                iters.pop()
                continue
            c = color.get(nxt, BLACK)
            if c == GRAY:
                return path[path.index(nxt):]
            if c == WHITE:
                color[nxt] = GRAY
                path.append(nxt)
                iters.append(iter(sorted(deps.get(nxt, ()))))
    return None


@functools.lru_cache(maxsize=None)
def deadlock_cycle(topo: Topology, n_vcs: int) -> Optional[tuple[Channel, ...]]:
    """``None`` ⇒ the (topology, routing, VC assignment) combination is
    provably wormhole-deadlock-free; otherwise a concrete channel cycle.
    Cached per (topo, n_vcs) — topologies are frozen/hashable."""
    cyc = find_graph_cycle(build_cdg(topo, n_vcs))
    return tuple(cyc) if cyc else None


def format_channel_cycle(cycle: Sequence[Channel]) -> str:
    hops = " -> ".join(f"({u}->{v} vc{vc})" for u, v, vc in cycle)
    u0, v0, vc0 = cycle[0]
    return f"{hops} -> back to ({u0}->{v0} vc{vc0})"


def check_deadlock_freedom(topo: Topology, n_vcs: int,
                           where: str = "") -> list[Diagnostic]:
    """NOC001/NOC002 diagnostics for one (topology, n_vcs) combination."""
    if n_vcs < 1:
        return [diag("NOC002", f"n_vcs={n_vcs} must be >= 1", where)]
    cyc = deadlock_cycle(topo, n_vcs)
    if cyc is None:
        return []
    return [diag(
        "NOC001",
        f"{topo.name} n={topo.n_nodes} with n_vcs={n_vcs} has a cyclic "
        f"channel dependency — wormhole traffic can deadlock: "
        f"{format_channel_cycle(cyc)}; wrapped dimensions need n_vcs >= 2 "
        f"dateline escape channels", where)]


def find_wait_cycle(waits: Mapping[Hashable, Hashable]) -> Optional[list]:
    """Cycle in a wait-for map (each key waits on exactly one successor).

    Used by the runtime DeadlockError to name the culprit channels of a
    wedged simulation; returns the cycle in wait order, or None."""
    done: set = set()
    for start in waits:
        if start in done:
            continue
        pos: dict = {}
        path: list = []
        k = start
        while k in waits and k not in pos and k not in done:
            pos[k] = len(path)
            path.append(k)
            k = waits[k]
        if k in pos:
            return path[pos[k]:]
        done.update(path)
    return None
