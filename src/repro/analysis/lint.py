"""Config linter + whole-executor verification + the ``repro.analysis.lint`` CLI.

The linters turn misconfigurations that previously surfaced mid-simulation
(or not at all) into `Diagnostic` records with stable NOC0xx codes:

* :func:`lint_graph`       — PE-graph contract violations (NOC009);
* :func:`lint_placement`   — unknown PEs / out-of-range nodes (NOC007);
* :func:`lint_plan`        — pod-cut coverage, density, and channel
                             classification (NOC008);
* :func:`lint_noc_config`  — field validity (NOC012), serdes/flit framing
                             mismatches (NOC010), and — given a topology —
                             the channel-dependency deadlock proof (NOC001);
* :func:`lint_model_config`— MoE-over-NoC dispatch degradations (NOC011);
* :func:`verify_executor`  — everything above plus the delivery proofs and
                             capacity bounds for one `NoCExecutor`'s compiled
                             artifacts; this is what
                             ``NoCExecutor(verify="strict")`` runs.

CLI
---
``python -m repro.analysis.lint [apps] [configs] [benchmarks]`` sweeps the
three case-study app defaults (graphs compiled onto their default
topologies, verified end to end), every registered model architecture, and
the benchmark-table topology × traffic-pattern grid.  Errors exit 1
(warnings too with ``--strict-warnings``).
"""
from __future__ import annotations

import sys

from ..core.topology import TOPOLOGIES, Topology, make_topology
from .capacity import check_traffic, executor_bounds
from .cdg import check_deadlock_freedom
from .delivery import (verify_bridged_program, verify_route_program,
                       verify_wave_layout)
from .diagnostics import Diagnostic, diag, errors


def lint_graph(graph) -> list[Diagnostic]:
    """NOC009: contract violations in a `graph.TaskGraph`."""
    from ..core.graph import GraphError

    diags: list[Diagnostic] = []
    where = f"TaskGraph({graph.name})"
    try:
        graph.validate()
        graph.firing_order()
    except GraphError as e:
        diags.append(diag("NOC009", str(e), where))
    # channels appended without connect() bypass the contract check — redo it
    import numpy as np
    for c in graph.channels:
        w = f"{where}.channel({c.src_pe}.{c.src_port}->{c.dst_pe}.{c.dst_port})"
        try:
            sp = graph.pes[c.src_pe].out_port(c.src_port)
            dp = graph.pes[c.dst_pe].in_port(c.dst_port)
        except KeyError as e:
            diags.append(diag("NOC009", f"channel names a missing "
                                        f"endpoint: {e}", w))
            continue
        if sp.shape != dp.shape or np.dtype(sp.dtype) != np.dtype(dp.dtype):
            diags.append(diag(
                "NOC009", f"contract mismatch {sp.shape}/"
                          f"{np.dtype(sp.dtype)} vs {dp.shape}/"
                          f"{np.dtype(dp.dtype)}", w))
    return diags


def lint_placement(graph, topo: Topology, placement) -> list[Diagnostic]:
    """NOC007: every PE on a real node, every placed name a real PE."""
    diags: list[Diagnostic] = []
    n = topo.n_nodes
    for pe, node in placement.items():
        w = f"placement[{pe!r}]"
        if pe not in graph.pes:
            diags.append(diag("NOC007", "placement names a PE the graph "
                                        "does not have", w))
        if not 0 <= node < n:
            diags.append(diag("NOC007", f"node {node} outside the {n}-node "
                                        f"{topo.name}", w))
    missing = sorted(set(graph.pes) - set(placement))
    if missing:
        diags.append(diag("NOC007", f"PEs with no node assigned: "
                                    f"{missing[:6]}", "placement"))
    return diags


def lint_plan(graph, topo: Topology, plan) -> list[Diagnostic]:
    """NOC008: pod-cut coverage, pod-id validity, and channel classification."""
    diags = lint_placement(graph, topo, plan.placement)
    n = topo.n_nodes
    pod_of = tuple(plan.pod_of_node)
    where = "PartitionPlan"
    if len(pod_of) != n:
        diags.append(diag("NOC008", f"pod_of_node covers {len(pod_of)} "
                                    f"nodes, topology has {n}", where))
        return diags
    # pod ids are labels compared only for equality — a cut that leaves a pod
    # empty (all nodes on one side) is legal; only negative ids are malformed
    bad = sorted({p for p in pod_of if p < 0})
    if bad:
        diags.append(diag("NOC008", f"negative pod ids {bad} in pod_of_node",
                          where))
    if errors(diags):
        return diags
    want_intra, want_cross = [], []
    for c in graph.channels:
        same = pod_of[plan.placement[c.src_pe]] == pod_of[plan.placement[c.dst_pe]]
        (want_intra if same else want_cross).append(c.key())
    if sorted(c.key() for c in plan.intra) != sorted(want_intra) or \
            sorted(c.key() for c in plan.cross) != sorted(want_cross):
        diags.append(diag(
            "NOC008", "intra/cross channel classification disagrees with "
                      "placement × pod_of_node — a cut channel would run "
                      "without serdes endpoints (or vice versa)", where))
    return diags


def lint_noc_config(cfg, topo: Topology = None) -> list[Diagnostic]:
    """NOC012/NOC010 for a `noc.NoCConfig`; NOC001 proof given a topology."""
    diags: list[Diagnostic] = []
    for f in ("flit_data_width", "flit_buffer_depth", "bridge_fifo_depth",
              "switch_buffer_depth", "switch_vcs"):
        v = getattr(cfg, f)
        if v < 1:
            diags.append(diag("NOC012", f"{f}={v} must be >= 1",
                              f"NoCConfig.{f}"))
    if cfg.flit_data_width % 8:
        diags.append(diag(
            "NOC010", f"flit_data_width={cfg.flit_data_width} is not "
                      f"byte-aligned: every flit pads to "
                      f"{cfg.flit_wire_bytes}B of storage/wire",
            "NoCConfig.flit_data_width"))
    beat = cfg.serdes.beat_bytes
    fw = cfg.flit_wire_bytes
    if fw % beat and beat % fw:
        diags.append(diag(
            "NOC010", f"flit word ({fw}B) and serdes beat ({beat}B) do not "
                      f"tile each other: every pod crossing re-pads its "
                      f"frames", "NoCConfig.serdes.wire_bits"))
    if topo is not None and not errors(diags):
        diags.extend(check_deadlock_freedom(topo, cfg.switch_vcs,
                                            "NoCConfig.switch_vcs"))
    return diags


def lint_model_config(mc, n_ranks: int = None) -> list[Diagnostic]:
    """NOC011: MoE-over-NoC dispatch degradations in a `configs.ModelConfig`."""
    diags: list[Diagnostic] = []
    where = f"ModelConfig({mc.name})"
    has_moe = any("moe" in layer for layer in mc.pattern)
    if not has_moe:
        return diags
    if mc.n_experts < 1:
        diags.append(diag("NOC011", "pattern has moe layers but "
                                    "n_experts=0", f"{where}.n_experts"))
        return diags
    if mc.top_k < 1 or mc.top_k > mc.n_experts:
        diags.append(diag("NOC011", f"top_k={mc.top_k} outside "
                                    f"1..n_experts={mc.n_experts}",
                          f"{where}.top_k"))
    if mc.moe_impl == "noc" and mc.moe_topology not in TOPOLOGIES:
        diags.append(diag("NOC011", f"moe_topology={mc.moe_topology!r} is "
                                    f"not a known topology "
                                    f"({sorted(TOPOLOGIES)})",
                          f"{where}.moe_topology"))
    if n_ranks and mc.n_experts % n_ranks:
        diags.append(diag(
            "NOC011", f"n_experts={mc.n_experts} not divisible by "
                      f"{n_ranks} NoC ranks: dispatch falls back to the "
                      f"dense reference path (no NoC routing, no flit "
                      f"accounting)", f"{where}.n_experts"))
    return diags


def verify_executor(ex) -> list[Diagnostic]:
    """Full static verification of one `NoCExecutor`'s compiled artifacts.

    Composes the config/graph/placement linters, the delivery proofs over
    the compiled route (and bridged) programs and per-wave scatter/gather
    layouts, and the capacity bounds.  This is the body of
    ``NoCExecutor(verify=...)``."""
    from ..core.routing import compile_routes

    diags = lint_graph(ex.graph)
    diags.extend(lint_placement(ex.graph, ex.topo, ex.placement))
    diags.extend(lint_noc_config(ex.cfg, ex.topo))
    n = ex.topo.n_nodes
    for w, prog in enumerate(ex.programs):
        diags.extend(verify_wave_layout(prog, n, f"NoCExecutor.programs[{w}]",
                                        ex.cfg.flit_wire_bytes))
    if ex._route_prog is None:
        ex._route_prog = compile_routes(ex.topo)
    diags.extend(verify_route_program(ex._route_prog))
    if ex.plan is not None:
        diags.extend(lint_plan(ex.graph, ex.topo, ex.plan))
        if not errors(diags):
            try:
                diags.extend(verify_bridged_program(ex._ensure_bridge()))
            except ValueError as e:
                diags.append(diag("NOC008", f"bridge compilation failed: "
                                            f"{e}", "PartitionPlan"))
    if not errors(diags):
        diags.extend(executor_bounds(ex).diagnostics)
    return diags


# ---------------------------------------------------------------------------
# CLI: python -m repro.analysis.lint [apps] [configs] [benchmarks]
# ---------------------------------------------------------------------------

def _lint_apps() -> list[tuple[str, list[Diagnostic]]]:
    """Verify the three case-study apps' default compiled executors."""
    import numpy as np

    from ..apps import bmvm, ldpc, particle_filter as pf
    from ..core.noc import NoCExecutor
    from ..core.partition import place_round_robin

    out = []
    rng = np.random.default_rng(0)

    g, _ = ldpc.build_ldpc_graph(ldpc.fano_plane_H())
    topo = make_topology("mesh", 16)
    ex = NoCExecutor(g, topo, verify="off")
    out.append(("ldpc/mesh16", verify_executor(ex)))

    bcfg = bmvm.BMVMConfig(n=64, k=8, fold=2)
    lut = np.asarray(bmvm.preprocess(
        rng.integers(0, 2, (bcfg.n, bcfg.n), np.uint8), bcfg))
    g, _ = bmvm.build_bmvm_graph(lut, bcfg)
    topo = make_topology(bcfg.topology, 2 * bcfg.n_pe)
    ex = NoCExecutor(g, topo, verify="off")
    out.append((f"bmvm/{bcfg.topology}{topo.n_nodes}", verify_executor(ex)))

    pcfg = pf.PFConfig()
    g = pf.build_pf_graph(pcfg, 4)
    topo = make_topology("mesh", 8)
    ex = NoCExecutor(g, topo, placement=place_round_robin(g, topo),
                     verify="off")
    out.append(("particle_filter/mesh8", verify_executor(ex)))
    return out


def _lint_configs() -> list[tuple[str, list[Diagnostic]]]:
    """Lint every registered model architecture (full + smoke variants)."""
    from .. import configs

    out = []
    for name in configs.ALL_ARCHS:
        for smoke in (False, True):
            mc = configs.get_config(name, smoke=smoke)
            tag = f"configs/{name}" + ("/smoke" if smoke else "")
            out.append((tag, lint_model_config(mc, n_ranks=4)))
    return out


def _lint_benchmarks() -> list[tuple[str, list[Diagnostic]]]:
    """Lint the benchmark tables' topology × NoCConfig × traffic grid."""
    from ..core.noc import NoCConfig
    from ..core.traffic import PATTERNS, TrafficConfig

    out = []
    cfg = NoCConfig()
    combos = [("ring", 8), ("mesh", 16), ("torus", 16), ("fattree", 8)]
    for name, n in combos:
        topo = make_topology(name, n)
        out.append((f"bench/{name}{n}", lint_noc_config(cfg, topo)))
        for pattern in PATTERNS:
            tcfg = TrafficConfig(pattern=pattern, injection_rate=0.05,
                                 n_packets=8)
            out.append((f"bench/{name}{n}/{pattern}",
                        check_traffic(topo, tcfg, cfg.switch_vcs)))
    return out


_TARGETS = {"apps": _lint_apps, "configs": _lint_configs,
            "benchmarks": _lint_benchmarks}


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    strict = "--strict-warnings" in argv
    argv = [a for a in argv if a != "--strict-warnings"]
    targets = argv or sorted(_TARGETS)
    unknown = [t for t in targets if t not in _TARGETS]
    if unknown:
        print(f"unknown target(s) {unknown}; choose from {sorted(_TARGETS)}")
        return 2
    n_err = n_warn = 0
    for t in targets:
        for where, diags in _TARGETS[t]():
            n_err += len(errors(diags))
            n_warn += len(diags) - len(errors(diags))
            status = ("ok" if not diags else
                      "FAIL" if errors(diags) else "warn")
            print(f"[{status:4s}] {where}")
            for d in diags:
                print(f"        {d}")
    print(f"lint: {n_err} error(s), {n_warn} warning(s)")
    return 1 if n_err or (strict and n_warn) else 0


if __name__ == "__main__":
    sys.exit(main())
