"""Static delivery/conservation proofs over compiled routing artifacts.

The compiled stack has three layers of value-independent routing structure,
each verified here without moving a byte:

* :func:`verify_route_program` — a `routing.RouteProgram` is an explicit
  hop-permutation composition.  We execute it *symbolically*: per line phase,
  holder arrays track whose buffer each axis node holds after every hop move,
  so each commit (``out[i, src_table[i]] = buf[i, i]``) can be checked against
  the true holder, each hop permutation checked to be a single-step neighbor
  rotation in its buffer's direction, and the committed ``(dst, src)`` pair
  set checked to cover the axis all-to-all **exactly once** (conservation:
  every message delivered, none duplicated, none fabricated).  A 2D program's
  factorized composition then delivers iff each phase does and the phase
  sizes tile the node count — which is also checked.

* :func:`verify_bridged_program` — an `interchip.BridgedProgram` must agree
  with an independent re-walk of its base program: every pod-crossing hop of
  every round must map to a `BridgeLink` whose endpoints/pods match
  ``pod_of_node``, intra hops must stay intra, and the per-pod `PodProgram`
  views (nodes, per-round hops, egress/ingress bridges) must be exact
  projections.  Any cut hop without a matching bridge would silently move
  bytes across chips without a serdes endpoint.

* :func:`verify_wave_layout` — the executor's per-wave scatter/gather index
  vectors.  Given the proven transpose semantics of the transport
  (``delivered[d, s] == msgs[s, d]``), the wave delivers every payload byte
  exactly once iff ``pack_idx`` entries are unique, land inside their
  ``(src, dst)`` buffer's framed extent, and ``gather_idx`` is the exact
  source/destination-swapped image of ``pack_idx`` byte for byte.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..core.routing import LinePhase, RouteProgram
from .diagnostics import Diagnostic, diag


def _verify_line_phase(phase: LinePhase, where: str) -> list[Diagnostic]:
    """Symbolic execution of one compiled line phase (holder arrays)."""
    m = phase.sched.size
    wrap = phase.sched.wrap
    diags: list[Diagnostic] = []
    # holders[b][i]: whose buffer node i holds in rotating buffer b (-1: none)
    holders = [list(range(m)), list(range(m))]
    committed: dict[tuple[int, int], int] = {(i, i): 1 for i in range(m)}
    for r, rnd in enumerate(phase.rounds):
        for k, mv in enumerate(rnd.moves):
            w = f"{where}.rounds[{r}].moves[{k}]"
            if mv.buf not in (0, 1):
                diags.append(diag("NOC003", f"buf={mv.buf} names no rotating "
                                            f"buffer (0=fwd, 1=bwd)", w))
                continue
            step = 1 if mv.buf == 0 else -1
            srcs = [s for s, _ in mv.perm]
            dsts = [d for _, d in mv.perm]
            if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
                diags.append(diag("NOC003", "hop permutation reuses an "
                                            "endpoint (not a permutation)", w))
                continue
            bad = [(s, d) for s, d in mv.perm
                   if not (0 <= s < m and 0 <= d < m)
                   or (d != (s + step) % m if wrap else d != s + step)]
            if bad:
                diags.append(diag(
                    "NOC003", f"non-neighbor hop pairs {bad[:4]} for a "
                              f"{step:+d} move on a size-{m} "
                              f"{'ring' if wrap else 'line'}", w))
                continue
            cur = holders[mv.buf]
            nh = [-1] * m
            for s, d in mv.perm:
                nh[d] = cur[s]
            holders[mv.buf] = nh
            if len(mv.src_table) != m:
                diags.append(diag("NOC003", f"src_table length "
                                            f"{len(mv.src_table)} != axis "
                                            f"size {m}", w))
                continue
            for i, src in enumerate(mv.src_table):
                if src < 0:
                    continue
                if src >= m:
                    diags.append(diag("NOC003", f"src_table[{i}]={src} out "
                                                f"of range", w))
                    continue
                if nh[i] != src:
                    diags.append(diag(
                        "NOC003", f"node {i} commits the message of source "
                                  f"{src} but holds the buffer of "
                                  f"{'nobody' if nh[i] < 0 else nh[i]}", w))
                committed[(i, src)] = committed.get((i, src), 0) + 1
    missing = [(i, j) for i in range(m) for j in range(m)
               if (i, j) not in committed]
    if missing:
        diags.append(diag("NOC003", f"{len(missing)} (dst, src) pairs are "
                                    f"never delivered (first few: "
                                    f"{missing[:4]})", where))
    dup = sorted(k for k, v in committed.items() if v > 1)
    if dup:
        diags.append(diag("NOC003", f"{len(dup)} (dst, src) pairs are "
                                    f"delivered more than once (first few: "
                                    f"{dup[:4]})", where))
    return diags


def verify_route_program(prog: RouteProgram) -> list[Diagnostic]:
    """Prove a compiled program realizes the all-to-all transpose exactly."""
    where = f"RouteProgram({prog.topo_name})"
    diags: list[Diagnostic] = []
    if prog.fused:
        return diags     # single lax.all_to_all: transpose by definition
    sizes = [p.sched.size for p in prog.phases]
    want = int(np.prod(sizes, dtype=np.int64))
    if want != prog.n_nodes:
        diags.append(diag("NOC003", f"phase sizes {sizes} tile {want} nodes, "
                                    f"program claims {prog.n_nodes}", where))
    if len(prog.phases) == 2:
        # phases run X then Y; axes are declared (noc_y, ry), (noc_x, rx)
        (_, ry), (_, rx) = prog.axes
        if (prog.phases[0].sched.size, prog.phases[1].sched.size) != (rx, ry):
            diags.append(diag("NOC003", f"phase sizes {sizes} disagree with "
                                        f"mesh axes rx={rx}, ry={ry}", where))
    for i, phase in enumerate(prog.phases):
        diags.extend(_verify_line_phase(phase, f"{where}.phases[{i}]"))
    return diags


def verify_bridged_program(bprog) -> list[Diagnostic]:
    """Check a BridgedProgram against an independent re-walk of its base
    program: cut coverage, bridge tables, and per-pod projections."""
    from ..core.interchip import _walk_rounds

    prog = bprog.prog
    diags = verify_route_program(prog)
    n = prog.n_nodes
    pod_of = bprog.pod_of_node
    where = f"BridgedProgram({prog.topo_name})"
    if len(pod_of) != n:
        diags.append(diag("NOC008", f"pod_of_node covers {len(pod_of)} "
                                    f"nodes, program has {n}", where))
        return diags
    # pod ids are labels compared only for equality; empty pods are legal
    bad_ids = sorted({p for p in pod_of if p < 0})
    if bad_ids:
        diags.append(diag("NOC008", f"negative pod ids {bad_ids} in "
                                    f"pod_of_node", where))
    seen_links: set[tuple[int, int]] = set()
    for i, b in enumerate(bprog.bridges):
        w = f"{where}.bridges[{i}]"
        if not (0 <= b.src < n and 0 <= b.dst < n):
            diags.append(diag("NOC004", f"bridge endpoints ({b.src}, "
                                        f"{b.dst}) out of range", w))
            continue
        if (pod_of[b.src], pod_of[b.dst]) != (b.src_pod, b.dst_pod):
            diags.append(diag("NOC004", f"bridge pods ({b.src_pod}, "
                                        f"{b.dst_pod}) disagree with "
                                        f"pod_of_node ({pod_of[b.src]}, "
                                        f"{pod_of[b.dst]})", w))
        elif b.src_pod == b.dst_pod:
            diags.append(diag("NOC004", f"bridge ({b.src}->{b.dst}) joins a "
                                        f"link that never crosses the cut", w))
        if (b.src, b.dst) in seen_links:
            diags.append(diag("NOC004", f"duplicate bridge for link "
                                        f"({b.src}->{b.dst})", w))
        seen_links.add((b.src, b.dst))
    walked = list(_walk_rounds(prog))
    if len(walked) != len(bprog.rounds):
        diags.append(diag("NOC004", f"{len(bprog.rounds)} compiled rounds, "
                                    f"base program walks {len(walked)}",
                          where))
        return diags
    for r, ((den, pairs), rnd) in enumerate(zip(walked, bprog.rounds)):
        w = f"{where}.rounds[{r}]"
        if rnd.den != den:
            diags.append(diag("NOC004", f"den={rnd.den}, re-walk says {den} "
                                        f"(per-traversal byte share wrong)",
                              w))
        want_intra = sorted(p for p in pairs if pod_of[p[0]] == pod_of[p[1]])
        if sorted(rnd.intra) != want_intra:
            diags.append(diag("NOC004", "intra-pod hop set disagrees with "
                                        "the re-walk", w))
        want_cross = sorted(p for p in pairs if pod_of[p[0]] != pod_of[p[1]])
        got_cross = []
        for bidx in rnd.cross:
            if not 0 <= bidx < len(bprog.bridges):
                diags.append(diag("NOC004", f"cross index {bidx} names no "
                                            f"bridge", w))
                continue
            b = bprog.bridges[bidx]
            got_cross.append((b.src, b.dst))
        if sorted(got_cross) != want_cross:
            missing = [p for p in want_cross if p not in got_cross]
            extra = [p for p in got_cross if p not in want_cross]
            diags.append(diag(
                "NOC004", f"cut hops without a matching BridgeLink: "
                          f"{missing[:4]}; bridged hops the schedule never "
                          f"drives: {extra[:4]}", w))
    for p, pod in enumerate(bprog.pods):
        w = f"{where}.pods[{p}]"
        want_nodes = tuple(i for i in range(n) if pod_of[i] == p)
        if pod.pod != p or pod.nodes != want_nodes:
            diags.append(diag("NOC004", f"pod view claims pod {pod.pod} "
                                        f"nodes {pod.nodes}, partition says "
                                        f"pod {p} nodes {want_nodes}", w))
            continue
        if len(pod.rounds) != len(bprog.rounds):
            diags.append(diag("NOC004", f"pod view has {len(pod.rounds)} "
                                        f"rounds, program {len(bprog.rounds)}",
                              w))
            continue
        for r, rnd in enumerate(bprog.rounds):
            want = tuple(pr for pr in rnd.intra if pod_of[pr[0]] == p)
            if pod.rounds[r] != want:
                diags.append(diag("NOC004", f"round {r} hops are not the "
                                            f"pod-{p} projection of the "
                                            f"program round", w))
        want_eg = tuple(i for i, b in enumerate(bprog.bridges)
                        if b.src_pod == p)
        want_in = tuple(i for i, b in enumerate(bprog.bridges)
                        if b.dst_pod == p)
        if pod.egress != want_eg or pod.ingress != want_in:
            diags.append(diag("NOC004", "egress/ingress bridge lists are "
                                        "not the partition's projections", w))
    return diags


def verify_wave_layout(prog, n: int, where: str,
                       flit_wire_bytes: Optional[int] = None) -> list[Diagnostic]:
    """Conservation proof for one compiled `_WaveProgram` layout.

    ``prog`` duck-types the executor's wave program: ``pack_idx``,
    ``gather_idx``, ``payload_nbytes``, ``buf_bytes``, ``slots``, ``pairs``."""
    diags: list[Diagnostic] = []
    pack = np.asarray(prog.pack_idx)
    gather = np.asarray(prog.gather_idx)
    nb = prog.buf_bytes
    if pack.shape != gather.shape or pack.size != prog.payload_nbytes:
        diags.append(diag("NOC003", f"index vectors cover {pack.size}/"
                                    f"{gather.size} bytes, payload is "
                                    f"{prog.payload_nbytes}", where))
        return diags
    if pack.size == 0:
        return diags
    if pack.min() < 0 or pack.max() >= n * n * nb:
        diags.append(diag("NOC003", "pack_idx leaves the message cube",
                          where))
        return diags
    if np.unique(pack).size != pack.size:
        diags.append(diag("NOC003", "pack_idx scatters two payload bytes to "
                                    "one cube byte (messages overlap)",
                          where))
    pair, off = np.divmod(pack, nb)
    s, d = np.divmod(pair, n)
    want_gather = (d * n + s) * nb + off
    if not np.array_equal(gather, want_gather):
        k = int(np.argmax(gather != want_gather))
        diags.append(diag(
            "NOC003", f"gather_idx[{k}] reads cube byte {int(gather[k])} "
                      f"but the transpose of pack_idx[{k}] is "
                      f"{int(want_gather[k])} — a byte delivered to the "
                      f"wrong (src, dst) slot", where))
    extent = np.zeros(n * n, np.int64)
    for ps, pd, pnb in prog.pairs:
        extent[ps * n + pd] = pnb
    over = off >= extent[pair]
    if over.any():
        k = int(np.argmax(over))
        diags.append(diag(
            "NOC003", f"payload byte {k} lands at offset {int(off[k])} of "
                      f"pair ({int(s[k])}, {int(d[k])}) past its framed "
                      f"extent {int(extent[int(pair[k])])}", where))
    if flit_wire_bytes is not None:
        ragged = [(ps, pd, pnb) for ps, pd, pnb in prog.pairs
                  if pnb % flit_wire_bytes]
        if ragged:
            diags.append(diag(
                "NOC003", f"pair extents not whole flits of "
                          f"{flit_wire_bytes}B: {ragged[:4]}", where))
    return diags
