"""Diagnostic plumbing for the static NoC verifier.

Every analysis in `repro.analysis` reports findings as :class:`Diagnostic`
records with a **stable error code** (``NOC001``-style), a fixed severity, a
human message, and a source pointer (``where``) naming the config field /
artifact the finding is anchored to.  Codes are registered once in
:data:`CODES`; analyses construct diagnostics through :func:`diag` so the
code → severity mapping cannot drift between call sites.

``error`` diagnostics are violations of a proven property (a deadlockable
channel-dependency cycle, a mis-delivered compiled route, an invalid
placement): executing the artifact can wedge, drop, or corrupt traffic.
``warning`` diagnostics are predictions of degraded-but-correct behavior
(FIFO saturation, serdes framing padding, offered load past saturation).

:class:`VerificationError` is what ``NoCExecutor(verify="strict")`` raises —
a ``ValueError`` carrying the full diagnostic list so callers can match on
codes programmatically.
"""
from __future__ import annotations

import dataclasses

ERROR = "error"
WARNING = "warning"

#: code -> (severity, one-line description).  Append-only: codes are stable
#: identifiers that tests, CI logs, and downstream tooling match on.
CODES: dict[str, tuple[str, str]] = {
    "NOC001": (ERROR, "channel-dependency cycle: (topology, n_vcs) can "
                      "deadlock under wormhole switching"),
    "NOC002": (ERROR, "invalid switch parameter (buffer depth / VC count)"),
    "NOC003": (ERROR, "compiled route program violates exactly-once "
                      "delivery/conservation"),
    "NOC004": (ERROR, "bridged program cut mismatch (cut hop without a "
                      "BridgeLink, or inconsistent pod tables)"),
    "NOC005": (WARNING, "switch input FIFO predicted to saturate "
                        "(peak occupancy reaches buffer depth)"),
    "NOC006": (WARNING, "offered traffic load exceeds the analytic "
                        "saturation rate"),
    "NOC007": (ERROR, "invalid placement (unknown PE or node out of range)"),
    "NOC008": (ERROR, "invalid pod cut (coverage, pod ids, or channel "
                      "classification)"),
    "NOC009": (ERROR, "PE graph contract violation (shape/dtype mismatch, "
                      "double-written port, or dataflow cycle)"),
    "NOC010": (WARNING, "serdes framing mismatch (flit word and wire beat "
                        "sizes force padding on every crossing)"),
    "NOC011": (WARNING, "MoE dispatch config degrades (expert count not "
                        "divisible across ranks, or unusable knobs)"),
    "NOC012": (ERROR, "invalid NoCConfig field (non-positive width/depth/"
                      "VC count)"),
    "NOC013": (WARNING, "bridge FIFO predicted to back-pressure (peak "
                        "occupancy reaches fifo_depth)"),
    "NOC014": (ERROR, "traffic config unusable on this topology "
                      "(no destinations, or hotspot out of range)"),
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding of a static analysis: code + severity + pointer + message."""

    code: str
    severity: str
    message: str
    where: str = ""

    def __str__(self) -> str:
        loc = f" [{self.where}]" if self.where else ""
        return f"{self.code} {self.severity}{loc}: {self.message}"


def diag(code: str, message: str, where: str = "") -> Diagnostic:
    """Construct a Diagnostic with the registered severity for ``code``."""
    severity, _ = CODES[code]
    return Diagnostic(code, severity, message, where)


def errors(diags: list[Diagnostic]) -> list[Diagnostic]:
    return [d for d in diags if d.severity == ERROR]


def format_diagnostics(diags: list[Diagnostic]) -> str:
    n_err = len(errors(diags))
    head = (f"{len(diags)} finding(s), {n_err} error(s):"
            if diags else "no findings")
    return "\n".join([head] + [f"  {d}" for d in diags])


class VerificationError(ValueError):
    """Static verification failed: one or more error-severity diagnostics.

    ``.diagnostics`` holds every finding (warnings included) so callers can
    match codes; ``str()`` renders the full report."""

    def __init__(self, diags: list[Diagnostic]):
        self.diagnostics = list(diags)
        super().__init__(format_diagnostics(self.diagnostics))
