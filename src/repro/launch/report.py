"""Post-process dry-run JSONs into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m repro.launch.report results/dryrun [--md]
    PYTHONPATH=src python -m repro.launch.report --trace trace.json [--csv]
    PYTHONPATH=src python -m repro.launch.report --profile trace.json

``--trace`` renders the link-utilization heatmap of a recorded Perfetto/
Chrome trace (see ``python -m repro.telemetry``) instead of the roofline
table — the NoC-side communication report next to the TPU-side one.
``--profile`` runs the latency profiler over the same saved trace
(``repro.telemetry.events_from_chrome`` → ``profile_trace``) and prints
the bottleneck report: exact per-packet latency decomposition, critical
path and the gap attribution against the analytic bounds (see
``docs/observability.md``).

Adds the algorithm-ideal terms the raw records can't know:
  ideal_compute_s = MODEL_FLOPS/chips / peak
  ideal_memory_s  = MODEL_BYTES/chips / HBM_bw   (params + cache traffic floor)
  roofline_fraction = max(ideal terms) / achieved step time
                      (the headline score: 1.0 = at the roofline for what the
                       algorithm fundamentally must compute/move)
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import jax

from ..configs import SHAPES, get_config
from .roofline import HBM_BW, PEAK_FLOPS


def cache_bytes(cfg, shape) -> int:
    from .steps import cache_struct
    total = 0
    for leaf in jax.tree.leaves(cache_struct(cfg, shape.global_batch, shape.seq_len)):
        total += leaf.size * leaf.dtype.itemsize
    return total


def model_bytes(cfg, shape) -> float:
    """Algorithm-minimum HBM traffic per step (global bytes).

    train:   read+write params/m/v in f32 (24 B/param) + bf16 cast reads (2)
    prefill: param reads (2 B active) + cache writes
    decode:  param reads (2 B active) + full cache read
    """
    n = cfg.param_count()
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        return 24.0 * n + 2.0 * n_act
    cb = cache_bytes(cfg, shape)
    if shape.kind == "prefill":
        return 2.0 * n_act + cb
    return 2.0 * n_act + cb  # decode: read the whole cache once


def enrich(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    if rec["shape"] == "long_500k":
        cfg = cfg.replace(seq_shard_kv=True)
    shape = SHAPES[rec["shape"]]
    n_chips = 512 if rec["mesh"] == "2x16x16" else 256
    rc = rec.get("roofline_corrected") or {}
    if rec["status"] != "ok" or "error" in rc:
        return rec
    mb = model_bytes(cfg, shape)
    ideal_c = rc["model_flops_global"] / n_chips / PEAK_FLOPS
    ideal_m = mb / n_chips / HBM_BW
    achieved = max(rc["compute_s"], rc["memory_s"], rc["collective_s"])
    rc["ideal_compute_s"] = ideal_c
    rc["ideal_memory_s"] = ideal_m
    rc["model_bytes_global"] = mb
    rc["roofline_fraction"] = max(ideal_c, ideal_m) / achieved if achieved else 0.0
    rec["roofline_corrected"] = rc
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("dir", nargs="?", default=None,
                    help="dry-run results directory (roofline table)")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--trace", default=None, metavar="TRACE_JSON",
                    help="render the link-utilization heatmap of a "
                         "telemetry trace instead of the roofline table")
    ap.add_argument("--csv", action="store_true",
                    help="with --trace: CSV rows instead of the matrix")
    ap.add_argument("--profile", default=None, metavar="TRACE_JSON",
                    help="print the latency profiler's bottleneck report "
                         "for a saved telemetry trace")
    args = ap.parse_args()
    if args.profile is not None:
        from ..telemetry import events_from_chrome, profile_trace
        with open(args.profile) as fh:
            doc = json.load(fh)
        print(profile_trace(events_from_chrome(doc)).check_exact().report())
        return
    if args.trace is not None:
        from ..telemetry import heatmap, link_utilization
        with open(args.trace) as fh:
            doc = json.load(fh)
        print(heatmap(link_utilization(doc), csv=args.csv))
        return
    if args.dir is None:
        ap.error("either a results dir or --trace is required")
    rows = []
    for f in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        rec = enrich(json.load(open(f)))
        with open(f, "w") as fh:
            json.dump(rec, fh, indent=1)
        rows.append(rec)
    hdr = ("arch", "shape", "mesh", "status", "dom", "compute_s", "memory_s",
           "collective_s", "ideal_c", "ideal_m", "roofline_frac")
    if args.md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(",".join(hdr))
    for r in rows:
        rc = r.get("roofline_corrected") or {}
        if r["status"] == "ok" and "error" not in rc:
            vals = (r["arch"], r["shape"], r["mesh"], "ok", rc["dominant"],
                    f"{rc['compute_s']:.4f}", f"{rc['memory_s']:.4f}",
                    f"{rc['collective_s']:.4f}", f"{rc['ideal_compute_s']:.4f}",
                    f"{rc['ideal_memory_s']:.4f}", f"{rc['roofline_fraction']:.3f}")
        else:
            vals = (r["arch"], r["shape"], r["mesh"], r["status"],
                    str(r.get("reason") or r.get("error", ""))[:60], "", "", "", "", "", "")
        if args.md:
            print("| " + " | ".join(vals) + " |")
        else:
            print(",".join(vals))


if __name__ == "__main__":
    main()
