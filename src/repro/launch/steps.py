"""Jittable step functions (train / prefill / decode) with explicit
shardings — shared by the trainer, the server, and the dry-run.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..configs.base import ModelConfig, ShapeConfig
from ..core.partition import DEFAULT_RULES, cross_pod_mean
from ..core.serdes import QuasiSerdesConfig
from ..models import transformer as T
from ..models.layers import param_pspecs, param_shapes
from ..optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule


def batch_pspec(mesh: Mesh) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(axes if axes else None)


def shardings_for_params(cfg: ModelConfig, mesh: Mesh):
    specs = T.abstract_params(cfg)
    pspecs = param_pspecs(specs, DEFAULT_RULES, mesh.axis_names, dict(mesh.shape))
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspecs)


def batch_shardings(batch_specs: dict, mesh: Mesh, shape: ShapeConfig):
    bp = batch_pspec(mesh)

    n_batch = 1
    for a in (bp[0] if isinstance(bp[0], tuple) else ((bp[0],) if bp[0] else ())):
        n_batch *= mesh.shape[a]

    def of(k, v):
        if (v.ndim >= 2 and v.shape[0] == shape.global_batch
                and shape.global_batch % max(n_batch, 1) == 0):
            return NamedSharding(mesh, P(bp[0], *([None] * (v.ndim - 1))))
        return NamedSharding(mesh, P())

    return {k: of(k, v) for k, v in batch_specs.items()}


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, mesh: Mesh, opt_cfg: AdamWConfig,
                    *, pod_sync: str = "auto",
                    serdes: Optional[QuasiSerdesConfig] = None,
                    total_steps: int = 10_000, warmup: int = 200):
    """pod_sync:
      'auto'   — flat XLA all-reduce over (pod, data)  [baseline]
      'serdes' — per-pod grads via shard_map(auto over data/model), cross-pod
                 exchange through quasi-SERDES endpoints  [paper-faithful cut]
    """
    n_pods = mesh.shape.get("pod", 1)

    def lr_of(step):
        return cosine_schedule(step, peak_lr=opt_cfg.lr, warmup=warmup,
                               total=total_steps)

    def grads_auto(params, batch):
        (loss, mets), grads = jax.value_and_grad(T.loss, has_aux=True)(params, batch, cfg)
        return loss, mets, grads

    def grads_serdes(params, batch):
        """Fully-manual shard_map region (manual over *every* mesh axis).

        The earlier partial-manual lowering (manual over 'pod' only, data/model
        auto inside) trips old XLA's ``sharding.IsManualSubgroup()`` check on
        the pinned jax 0.4.37.  Fully-manual sidesteps it on old and new jax
        alike: params enter replicated, each device computes grads on its own
        (pod × data) batch shard, the within-pod average is an explicit pmean
        over 'data' (the on-chip all-reduce), and only the cross-pod exchange
        goes through the quasi-SERDES endpoints over the cut.  Model-axis
        devices redundantly compute identical grads — the replication that
        makes the region's outputs valid under ``out_specs=P()``."""
        data_axes = tuple(a for a in ("data",) if a in mesh.axis_names)
        sync_axes = ("pod",) + data_axes

        def pod_local(params, batch):
            (loss, mets), grads = jax.value_and_grad(T.loss, has_aux=True)(params, batch, cfg)
            if data_axes:
                grads = jax.tree.map(lambda g: lax.pmean(g, data_axes), grads)
            grads, _ = cross_pod_mean(grads, "pod", serdes, n_pods=n_pods,
                                      serialized=True)
            loss = lax.pmean(loss, sync_axes)
            mets = jax.tree.map(lambda m: lax.pmean(m, sync_axes), mets)
            return loss, mets, grads

        blead = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        bspec = jax.tree.map(lambda _: P(blead), batch)
        return shard_map(
            pod_local, mesh=mesh,
            in_specs=(P(), bspec), out_specs=(P(), P(), P()),
            check_vma=False)(params, batch)

    grads_fn = grads_auto if (pod_sync == "auto" or n_pods == 1) else grads_serdes

    def train_step(state, batch):
        params, opt_state = state["params"], state["opt"]
        loss, mets, grads = grads_fn(params, batch)
        new_params, new_opt, om = adamw_update(params, grads, opt_state, opt_cfg,
                                               lr=lr_of(opt_state["step"]))
        mets = dict(mets, loss=loss, **om)
        return {"params": new_params, "opt": new_opt}, mets

    return train_step


def jit_train_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                   opt_cfg: AdamWConfig = AdamWConfig(), **kw):
    """Returns (jitted fn, state_specs, batch ShapeDtypeStructs) for lowering."""
    from ..configs.base import input_specs

    step = make_train_step(cfg, mesh, opt_cfg, **kw)
    psh = shardings_for_params(cfg, mesh)
    state_sh = {"params": psh,
                "opt": {"m": psh, "v": psh, "step": NamedSharding(mesh, P())}}
    bspecs = input_specs(cfg, shape)
    bsh = batch_shardings(bspecs, mesh, shape)
    jitted = jax.jit(step, in_shardings=(state_sh, bsh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
    pshapes = param_shapes(T.abstract_params(cfg))
    opt_shapes = jax.eval_shape(adamw_init, pshapes)
    state_shapes = {"params": pshapes, "opt": opt_shapes}
    return jitted, state_shapes, bspecs


# ---------------------------------------------------------------------------
# serve
# ---------------------------------------------------------------------------

def cache_struct(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: T.init_cache(cfg, batch, max_len))


def serve_param_shapes(cfg: ModelConfig):
    shp = param_shapes(T.abstract_params(cfg))
    if cfg.serve_param_dtype == "bfloat16":
        shp = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16), shp)
    return shp


def jit_prefill(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig):
    from ..configs.base import input_specs

    psh = shardings_for_params(cfg, mesh)
    bspecs = input_specs(cfg, shape)
    bsh = batch_shardings(bspecs, mesh, shape)
    extra = cfg.n_patches if cfg.family == "vlm" else 0
    cstruct = cache_struct(cfg, shape.global_batch, shape.seq_len + extra)

    def fn(params, batch, cache):
        return T.prefill(params, batch, cfg, cache)

    jitted = jax.jit(fn, in_shardings=(psh, bsh, None), donate_argnums=(2,))
    return jitted, bspecs, cstruct


def jit_decode(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig):
    """One decode step against a cache holding shape.seq_len tokens."""
    from ..configs.base import input_specs

    psh = shardings_for_params(cfg, mesh)
    bspecs = input_specs(cfg, shape)
    bsh = batch_shardings(bspecs, mesh, shape)
    cstruct = cache_struct(cfg, shape.global_batch, shape.seq_len)
    if cfg.family == "encdec":
        cstruct = dict(cstruct)
        cstruct["enc_out"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.enc_seq, cfg.d_model), cfg.cdtype)
    # cache starts at seq_len - 1 (full context), decode appends 1 token
    cstruct = dict(cstruct)

    def fn(params, batch, cache):
        return T.decode_step(params, batch, cfg, cache)

    jitted = jax.jit(fn, in_shardings=(psh, bsh, None), donate_argnums=(2,))
    return jitted, bspecs, cstruct
