"""Roofline-term extraction from a lowered/compiled dry-run artifact.

Three terms per (arch × shape × mesh), in seconds:

  compute    = HLO_FLOPs_per_chip / peak_FLOPs        (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes_per_chip / HBM_bw            (819 GB/s)
  collective = Σ collective_bytes_per_chip / (links·link_bw)   (~50 GB/s/link)

``cost_analysis()`` on an SPMD-partitioned module reports the per-device
program, so terms are per-chip directly.  Collective bytes are NOT in
cost_analysis — we parse the post-SPMD HLO text and sum output-shape bytes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(output bytes ≈ bytes put on the wire per chip for AR/AG; a stated,
consistent convention).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / ICI link
ICI_LINKS = 4                # usable links/chip on a 2D-torus v5e slice

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops that anchor a fusion cluster on TPU — the tensors that actually hit HBM.
_MEM_ANCHORS = ("dot", "convolution", "reduce", "reduce-window", "scatter",
                "gather", "dynamic-update-slice", "dynamic-slice", "sort",
                "concatenate", "cumsum", "iota-nope")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[128,4096]' or tuple '(bf16[...], f32[...])' -> total bytes."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-op-kind output bytes summed over every collective instruction."""
    out = {k: 0 for k in _COLLECTIVES}
    out["n_ops"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        # '%name = TYPE[SHAPE] op-name(...)' — find 'op-name(' after '='
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", ls)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        for kind in _COLLECTIVES:
            if op == kind or op.startswith(kind + "-start"):
                out[kind] += _shape_bytes(shape_str)
                out["n_ops"] += 1
                break
    return out


def fusion_aware_bytes(hlo_text: str) -> float:
    """Approximate post-fusion HBM traffic: 2× output bytes of every anchor
    op (read+write of the materialized tensor) + parameter reads once.
    Rationale: on TPU, elementwise chains fuse into their anchor (dot/reduce/
    slice/…); raw cost_analysis 'bytes accessed' counts every unfused
    elementwise op and overstates traffic ~10-30×.  Stated convention for the
    roofline memory term (EXPERIMENTS.md §Roofline)."""
    total = 0.0
    in_entry = False
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("ENTRY"):
            in_entry = True
        elif ls.startswith("}"):
            in_entry = False
        elif (ls.startswith("%") or ls.startswith("fused_") or ls.startswith("wide.")) and ls.endswith("{"):
            in_entry = False
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", ls)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if op == "parameter":
            if in_entry:  # fusion-body parameters are aliases, not HBM reads
                total += _shape_bytes(shape_str)
        elif op in _MEM_ANCHORS or op.startswith("reduce-"):
            total += 2.0 * _shape_bytes(shape_str)
    return total


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    collective_ops: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_global: float
    useful_flops_frac: float
    peak_fraction: float          # useful model FLOPs/chip/peak vs dominant term

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, hlo_text: str, *, n_chips: int, model_flops_global: float,
            mem_bytes_override: Optional[float] = None) -> Roofline:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(mem_bytes_override if mem_bytes_override is not None
                 else ca.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    cbytes = float(sum(coll[k] for k in _COLLECTIVES))
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = cbytes / (ICI_LINKS * LINK_BW)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops_global / n_chips / max(flops, 1.0)
    step_time = max(compute_s, memory_s, collective_s)
    ideal = (model_flops_global / n_chips) / PEAK_FLOPS
    return Roofline(flops, byts, cbytes, int(coll["n_ops"]), compute_s, memory_s,
                    collective_s, dominant, model_flops_global,
                    min(useful, 1.0), (ideal / step_time) if step_time > 0 else 0.0)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N_active·D for train, 2·N_active·D forward-only."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def extrapolate(c1: dict, c2: dict, n_periods: int, *, n_chips: int,
                model_flops_global: float) -> dict:
    """Affine trip-count correction: cost(P) = c0 + P·Δ from depth-1/2 lowers
    (inner sequence loops flattened there, so each period is counted exactly).
    """
    out = {}
    full = {}
    for k in ("flops", "bytes", "coll_bytes"):
        delta = max(c2[k] - c1[k], 0.0)
        full[k] = c1[k] + (n_periods - 1) * delta
    compute_s = full["flops"] / PEAK_FLOPS
    memory_s = full["bytes"] / HBM_BW
    collective_s = full["coll_bytes"] / (ICI_LINKS * LINK_BW)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())
    ideal = (model_flops_global / n_chips) / PEAK_FLOPS
    out.update(flops_per_chip=full["flops"], bytes_per_chip=full["bytes"],
               collective_bytes_per_chip=full["coll_bytes"],
               compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
               dominant=dominant,
               model_flops_global=model_flops_global,
               useful_flops_frac=min((model_flops_global / n_chips) / max(full["flops"], 1.0), 1.0),
               peak_fraction=(ideal / step_time) if step_time > 0 else 0.0)
    return out
