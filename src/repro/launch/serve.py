"""Serving driver: batched prefill + decode with a continuous request queue.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \\
        --requests 16 --batch 4 --prompt-len 32 --gen 16

Implements the batched serving loop the decode shapes lower: requests are
grouped into fixed-size batches, each batch is prefilled once, then decoded
token-by-token with a shared ring cache (greedy sampling).

``--metrics PATH`` turns on the telemetry metrics registry: prefill and
per-token decode wall-clock land in the ``serve.prefill.seconds`` /
``serve.decode.seconds`` histograms; the JSON snapshot (with p50/p99/p99.9)
is written to PATH ('-' = stdout).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models import transformer as T
from ..models.layers import init_params
from .mesh import make_host_mesh, set_mesh


def serve_batch(params, cfg, prompts: np.ndarray, gen: int, mesh,
                reg=None) -> np.ndarray:
    """One batch: prefill once, decode token-by-token.  ``reg``: an optional
    telemetry MetricsRegistry — per-phase wall clock is observed into the
    ``serve.prefill.seconds`` / ``serve.decode.seconds`` histograms (each
    sample is synced via the host round-trip, so it bounds real latency)."""
    B, S = prompts.shape
    with set_mesh(mesh):
        cache = T.init_cache(cfg, B, S + gen)
        batch = {"tokens": jnp.asarray(prompts)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((B, cfg.enc_seq, cfg.d_frontend), cfg.cdtype)
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros((B, cfg.n_patches, cfg.d_frontend), cfg.cdtype)
        prefill = jax.jit(lambda p, b, c: T.prefill(p, b, cfg, c))
        decode = jax.jit(lambda p, b, c: T.decode_step(p, b, cfg, c))
        ts = time.perf_counter()
        logits, cache = prefill(params, batch, cache)
        tok = jnp.argmax(logits[:, -1], -1)
        out = [np.asarray(tok)]
        if reg is not None:
            reg.histogram("serve.prefill.seconds").observe(
                time.perf_counter() - ts)
        for _ in range(gen - 1):
            ts = time.perf_counter()
            logits, cache = decode(params, {"tokens": tok[:, None]}, cache)
            tok = jnp.argmax(logits, -1)
            out.append(np.asarray(tok))
            if reg is not None:
                reg.histogram("serve.decode.seconds").observe(
                    time.perf_counter() - ts)
    return np.stack(out, 1)


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="enable the telemetry metrics registry; write the "
                         "JSON snapshot here ('-' prints to stdout)")
    args = ap.parse_args(argv)

    reg = None
    if args.metrics:
        from ..telemetry.metrics import enable_metrics
        reg = enable_metrics()
    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh(model=args.model_parallel)
    params = init_params(T.abstract_params(cfg), jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)

    t0 = time.monotonic()
    done = 0
    all_out = []
    while done < args.requests:
        n = min(args.batch, args.requests - done)
        prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
        out = serve_batch(params, cfg, prompts, args.gen, mesh, reg=reg)
        all_out.append(out[:n])
        done += n
        print(f"served {done}/{args.requests} requests "
              f"(batch decode tok/s so far: {done * args.gen / (time.monotonic() - t0):,.1f})")
    dt = time.monotonic() - t0
    print(f"done: {args.requests} requests × {args.gen} tokens in {dt:.1f}s")
    if reg is not None:
        import json as _json

        from ..telemetry.metrics import disable_metrics
        d = reg.histogram("serve.decode.seconds")
        print(f"decode/token: p50 {d.p50 * 1e3:.1f}ms  "
              f"p99 {d.p99 * 1e3:.1f}ms  p99.9 {d.p999 * 1e3:.1f}ms")
        # any NoC engine profiled in-process publishes noc.latency.*;
        # surface it next to the serve latencies (logical-clock ticks)
        for key, h in reg.histograms("noc.latency.").items():
            print(f"{key}: n={h.count} p50 {h.p50:.0f}  p99 {h.p99:.0f}  "
                  f"p99.9 {h.p999:.0f} ticks")
        snap = _json.dumps(reg.snapshot(), indent=1, sort_keys=True)
        if args.metrics == "-":
            print(snap)
        else:
            with open(args.metrics, "w") as fh:
                fh.write(snap + "\n")
            print(f"metrics snapshot -> {args.metrics}")
        disable_metrics()
    return np.concatenate(all_out)


if __name__ == "__main__":
    run()
