"""Serving driver: batched prefill + decode with a continuous request queue.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \\
        --requests 16 --batch 4 --prompt-len 32 --gen 16

Implements the batched serving loop the decode shapes lower: requests are
grouped into fixed-size batches, each batch is prefilled once, then decoded
token-by-token with a shared ring cache (greedy sampling).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models import transformer as T
from ..models.layers import init_params
from .mesh import make_host_mesh, set_mesh


def serve_batch(params, cfg, prompts: np.ndarray, gen: int, mesh) -> np.ndarray:
    B, S = prompts.shape
    with set_mesh(mesh):
        cache = T.init_cache(cfg, B, S + gen)
        batch = {"tokens": jnp.asarray(prompts)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((B, cfg.enc_seq, cfg.d_frontend), cfg.cdtype)
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros((B, cfg.n_patches, cfg.d_frontend), cfg.cdtype)
        prefill = jax.jit(lambda p, b, c: T.prefill(p, b, cfg, c))
        decode = jax.jit(lambda p, b, c: T.decode_step(p, b, cfg, c))
        logits, cache = prefill(params, batch, cache)
        tok = jnp.argmax(logits[:, -1], -1)
        out = [np.asarray(tok)]
        for _ in range(gen - 1):
            logits, cache = decode(params, {"tokens": tok[:, None]}, cache)
            tok = jnp.argmax(logits, -1)
            out.append(np.asarray(tok))
    return np.stack(out, 1)


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh(model=args.model_parallel)
    params = init_params(T.abstract_params(cfg), jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)

    t0 = time.monotonic()
    done = 0
    all_out = []
    while done < args.requests:
        n = min(args.batch, args.requests - done)
        prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
        out = serve_batch(params, cfg, prompts, args.gen, mesh)
        all_out.append(out[:n])
        done += n
        print(f"served {done}/{args.requests} requests "
              f"(batch decode tok/s so far: {done * args.gen / (time.monotonic() - t0):,.1f})")
    dt = time.monotonic() - t0
    print(f"done: {args.requests} requests × {args.gen} tokens in {dt:.1f}s")
    return np.concatenate(all_out)


if __name__ == "__main__":
    run()
