"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
jax initialization.
"""
from __future__ import annotations

import jax


def set_mesh(mesh):
    """Enter ``mesh`` as the ambient mesh, portably.

    ``jax.set_mesh`` only exists on newer jax; on older versions a ``Mesh`` is
    itself a context manager with the semantics the launch/serve/bench paths
    need, so fall back to it."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod (TPU v5e pod slice); 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever this host actually has (smoke tests, examples)."""
    n = jax.device_count()
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
