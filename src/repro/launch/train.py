"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \\
        --steps 200 --batch 8 --seq 128 --ckpt /tmp/ckpt

Runs on whatever devices the host has (CPU smoke / TPU slice), with the full
substrate engaged: sharded deterministic data pipeline, AdamW + cosine
schedule, remat, checkpoint/restart via the resilient runner, cross-pod
serdes gradient sync when the mesh has a pod axis.

``--metrics PATH`` turns on the telemetry metrics registry: wall-clock step
times land in the ``train.step.seconds`` histogram (p50/p99/p99.9 printed at
the end) and the per-step MoE NoC metrics publish under the shared
``noc.moe.*`` names; the JSON snapshot is written to PATH ('-' = stdout).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointConfig, CheckpointManager
from ..configs import get_config
from ..data import DataConfig, ShardedTokenPipeline
from ..models import transformer as T
from ..models.layers import init_params
from ..optim import AdamWConfig, adamw_init
from ..runtime import FTConfig, ResilientRunner
from .mesh import make_host_mesh, set_mesh
from .steps import make_train_step, shardings_for_params


def build_state(cfg, mesh, seed: int = 0):
    psh = shardings_for_params(cfg, mesh)
    specs = T.abstract_params(cfg)

    @jax.jit
    def init(key):
        return init_params(specs, key)

    with set_mesh(mesh):
        params = jax.jit(init, out_shardings=psh)(jax.random.key(seed))
        opt = jax.jit(adamw_init, out_shardings=None)(params)
    return {"params": params, "opt": opt}


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--pod-sync", default="auto", choices=["auto", "serdes"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="enable the telemetry metrics registry; write the "
                         "JSON snapshot here ('-' prints to stdout)")
    args = ap.parse_args(argv)

    reg = None
    if args.metrics:
        from ..telemetry.metrics import enable_metrics
        reg = enable_metrics()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh(model=args.model_parallel)
    opt_cfg = AdamWConfig(lr=args.lr)
    step_fn = make_train_step(cfg, mesh, opt_cfg, pod_sync=args.pod_sync,
                              total_steps=args.steps, warmup=max(args.steps // 20, 5))

    state = build_state(cfg, mesh, args.seed)
    n_params = cfg.param_count()
    print(f"arch={cfg.name} params={n_params:,} mesh={dict(mesh.shape)} "
          f"tokens/step={args.batch * args.seq}")

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
                      seed=args.seed)
    pipeline = ShardedTokenPipeline(dcfg)

    with set_mesh(mesh):
        jitted = jax.jit(step_fn, donate_argnums=(0,))
        losses = []

        def wrapped(state, batch):
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            if cfg.family == "encdec":
                jb["frames"] = jnp.zeros((args.batch, cfg.enc_seq, cfg.d_frontend),
                                         cfg.cdtype)
            if cfg.family == "vlm":
                jb["patches"] = jnp.zeros((args.batch, cfg.n_patches, cfg.d_frontend),
                                          cfg.cdtype)
            ts = time.perf_counter()
            state, mets = jitted(state, jb)
            loss = float(mets["loss"])   # blocks on the step's results
            if reg is not None:
                reg.histogram("train.step.seconds").observe(
                    time.perf_counter() - ts)
                reg.record_step_metrics(mets)
            losses.append(loss)
            n = len(losses)
            if n % args.log_every == 0 or n == 1:
                print(f"step {n:5d}  loss {losses[-1]:.4f}  "
                      f"gnorm {float(mets['grad_norm']):.3f}")
            return state

        if args.ckpt:
            cm = CheckpointManager(CheckpointConfig(args.ckpt, keep_last=2))
            runner = ResilientRunner(wrapped, cm,
                                     FTConfig(checkpoint_every=args.ckpt_every))
            start = cm.latest_step() or 0
            if start:
                state, start, _ = cm.restore(state)
                print(f"restored from step {start}")
            t0 = time.monotonic()
            state, stats = runner.run(state, pipeline, args.steps, start)
            dt = time.monotonic() - t0
        else:
            t0 = time.monotonic()
            for s in range(args.steps):
                state = wrapped(state, pipeline.batch_at(s))
            dt = time.monotonic() - t0
    pipeline.close()
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"done: {args.steps} steps in {dt:.1f}s ({tok_s:,.0f} tok/s); "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    if reg is not None:
        import json as _json

        from ..telemetry.metrics import disable_metrics
        h = reg.histogram("train.step.seconds")
        print(f"step time: p50 {h.p50 * 1e3:.1f}ms  p99 {h.p99 * 1e3:.1f}ms  "
              f"p99.9 {h.p999 * 1e3:.1f}ms")
        # any NoC engine profiled in-process publishes noc.latency.*;
        # surface it next to the step times (logical-clock ticks)
        for key, hh in reg.histograms("noc.latency.").items():
            print(f"{key}: n={hh.count} p50 {hh.p50:.0f}  p99 {hh.p99:.0f}  "
                  f"p99.9 {hh.p999:.0f} ticks")
        snap = _json.dumps(reg.snapshot(), indent=1, sort_keys=True)
        if args.metrics == "-":
            print(snap)
        else:
            with open(args.metrics, "w") as fh:
                fh.write(snap + "\n")
            print(f"metrics snapshot -> {args.metrics}")
        disable_metrics()
    return losses


if __name__ == "__main__":
    run()
