import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.

For every (architecture × input shape) cell, ``.lower().compile()`` the
appropriate step function (train_step / prefill / decode_step) against
ShapeDtypeStruct stand-ins on the production meshes:

    single-pod: (data=16, model=16)   = 256 chips
    multi-pod:  (pod=2, data=16, model=16) = 512 chips

and record memory_analysis / cost_analysis / collective schedule → the
roofline table (EXPERIMENTS.md §Dry-run, §Roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
(The XLA_FLAGS line above MUST run before any other jax-touching import —
this module keeps it as its first statement; nothing else in the repo sets
it globally.)
"""
import argparse
import json
import time
import traceback

from ..configs import ALL_ARCHS, SHAPES, cell_supported, get_config
from . import roofline as RL
from .mesh import make_production_mesh, set_mesh
from .steps import jit_decode, jit_prefill, jit_train_step


def _arch_overrides(cfg, shape):
    """Per-cell config adjustments (recorded in DESIGN.md):
    long-context decode shards KV/state sequence over 'data'."""
    if shape.name == "long_500k":
        cfg = cfg.replace(seq_shard_kv=True)
    return cfg


def _analysis_cfg(cfg, shape, m: int):
    """Depth-m variant with every inner sequence loop flattened, so XLA's
    cost_analysis (which counts a while body ONCE) is exact per period.
    Extrapolating the affine cost(P) from m=1,2 to the real depth gives
    trip-count-corrected totals (see roofline.extrapolate)."""
    kw = dict(n_layers=len(cfg.pattern) * m,
              analysis_unroll=True,
              mamba_chunk=max(shape.seq_len // 8, 16),
              xlstm_chunk=max(shape.seq_len // 8, 16))
    if cfg.n_enc_layers:
        kw["n_enc_layers"] = max(1, cfg.n_enc_layers // cfg.n_periods) * m
    return cfg.replace(**kw)


def _lower_cell(cfg, shape, mesh, step_kw=None):
    """Build + lower the right step fn; returns lowered."""
    if shape.kind == "train":
        jitted, state_shapes, bspecs = jit_train_step(cfg, mesh, shape,
                                                      **(step_kw or {}))
        return jitted.lower(state_shapes, bspecs)
    from .steps import serve_param_shapes
    if shape.kind == "prefill":
        jitted, bspecs, cstruct = jit_prefill(cfg, mesh, shape)
        return jitted.lower(serve_param_shapes(cfg), bspecs, cstruct)
    jitted, bspecs, cstruct = jit_decode(cfg, mesh, shape)
    return jitted.lower(serve_param_shapes(cfg), bspecs, cstruct)


def _cost_of(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    coll = RL.collective_bytes(hlo)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": RL.fusion_aware_bytes(hlo),
            "bytes_raw": float(ca.get("bytes accessed", 0.0)),
            "coll_bytes": float(sum(coll[k] for k in RL._COLLECTIVES)),
            "coll_ops": int(coll["n_ops"])}


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool,
                overrides: dict | None = None) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, why = cell_supported(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "status": "skip",
           "reason": why}
    if not ok:
        return rec
    cfg = _arch_overrides(cfg, shape)
    no_tp = False
    sp = False
    step_kw = {}
    if overrides:
        overrides = dict(overrides)
        no_tp = overrides.pop("no_tp", False)
        sp = overrides.pop("sp", False)
        if overrides.pop("pod_sync_serdes", False):
            from ..core.serdes import QuasiSerdesConfig
            step_kw = dict(pod_sync="serdes",
                           serdes=QuasiSerdesConfig(compress="bf16"))
        cfg = cfg.replace(**overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.monotonic()
    import contextlib
    from ..core.partition import NO_TP, rules_override
    if no_tp:
        rules_ctx = rules_override(**NO_TP)
    elif sp:  # sequence parallelism: activations seq-sharded over 'model'
        rules_ctx = rules_override(seq="model")
    else:
        rules_ctx = contextlib.nullcontext()
    try:
        with set_mesh(mesh), rules_ctx:
            lowered = _lower_cell(cfg, shape, mesh, step_kw)
            t_lower = time.monotonic() - t0
            compiled = lowered.compile()
            t_compile = time.monotonic() - t0 - t_lower
            hlo = compiled.as_text()
            mem = compiled.memory_analysis()
            mf = RL.model_flops(cfg, shape)
            roof = RL.analyze(compiled, hlo, n_chips=n_chips, model_flops_global=mf)
            # trip-count-corrected terms via depth-1/depth-2 extrapolation
            # (single-pod only: the roofline table is single-pod per spec;
            # the multi-pod pass proves the 'pod' axis shards)
            if multi_pod:
                corrected = {"error": "n/a (roofline table is single-pod)"}
            elif shape.name == "long_500k":
                # inline-unrolled analysis graphs of the 500k-cache decode hit
                # a pathological SPMD-partitioner compile; report measured
                # terms (no layer-scan undercount matters for the skip/ok
                # decision, and long cells are not hillclimb targets)
                corrected = {"error": "n/a (analysis lowering skipped for 500k cells)"}
            else:
                try:
                    c1 = _cost_of(_lower_cell(_analysis_cfg(cfg, shape, 1), shape, mesh).compile())
                    c2 = _cost_of(_lower_cell(_analysis_cfg(cfg, shape, 2), shape, mesh).compile())
                    corrected = RL.extrapolate(c1, c2, cfg.n_periods, n_chips=n_chips,
                                               model_flops_global=mf)
                except Exception as e:  # analysis failure must not fail the cell
                    corrected = {"error": f"{type(e).__name__}: {e}"}
            rec.update(
                status="ok",
                lower_s=round(t_lower, 1),
                compile_s=round(t_compile, 1),
                params=cfg.param_count(),
                active_params=cfg.active_param_count(),
                roofline=roof.as_dict(),
                roofline_corrected=corrected,
            )
            try:
                rec["memory"] = {
                    "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                    "output_bytes": getattr(mem, "output_size_in_bytes", None),
                    "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                    "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
                                  + (getattr(mem, "argument_size_in_bytes", 0) or 0),
                }
            except Exception:
                rec["memory"] = {"repr": repr(mem)}
    except Exception as e:
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--out", default=None, help="directory for per-cell json")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (e.g. moe_impl=noc)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except Exception:
            pass
        overrides[k] = v

    archs = [args.arch] if args.arch else list(ALL_ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)
    if args.out:
        os.makedirs(args.out, exist_ok=True)
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                if args.out:  # resume: skip cells already recorded OK
                    fn = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}.json".replace("/", "_")
                    fp = os.path.join(args.out, fn)
                    if os.path.exists(fp):
                        try:
                            old = json.load(open(fp))
                            if old.get("status") in ("ok", "skip"):
                                print(f"SKIP(cached) {arch} × {shape} × {old['mesh']}")
                                continue
                        except Exception:
                            pass
                rec = dryrun_cell(arch, shape, multi_pod=mp, overrides=overrides or None)
                tag = f"{arch} × {shape} × {rec['mesh']}"
                if rec["status"] == "ok":
                    r = rec.get("roofline_corrected") or rec["roofline"]
                    if "error" in r:
                        r = rec["roofline"]
                    print(f"OK   {tag}: compile {rec['compile_s']}s, "
                          f"dominant={r['dominant']} "
                          f"c/m/coll = {r['compute_s']:.4f}/{r['memory_s']:.4f}/"
                          f"{r['collective_s']:.4f}s  peak_frac={r['peak_fraction']:.3f}")
                elif rec["status"] == "skip":
                    print(f"SKIP {tag}: {rec['reason']}")
                else:
                    n_fail += 1
                    print(f"FAIL {tag}: {rec['error']}")
                if args.out:
                    fn = f"{arch}__{shape}__{rec['mesh']}.json".replace("/", "_")
                    with open(os.path.join(args.out, fn), "w") as f:
                        json.dump(rec, f, indent=1)
    print(f"\ndone; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
