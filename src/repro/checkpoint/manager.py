"""Fault-tolerant checkpointing: atomic, sharded, async, resharding-aware.

Layout (one directory per step):

    ckpt_dir/
      step_000100/
        meta.json            # treedef paths, shapes, dtypes, step, extra state
        arrays_00.npz        # flat leaves, chunked into volumes
        COMMITTED            # sentinel written LAST (atomicity marker)
      step_000200/ ...

Crash-safety contract:
* a checkpoint is valid iff COMMITTED exists; restore() scans for the newest
  valid step and ignores torn writes (tested by truncating a volume);
* save is write-to-temp + os.replace (atomic on POSIX) per file, sentinel last;
* async mode: device→host fetch happens synchronously (cheap), serialization
  + disk IO on a background thread so the train loop isn't blocked; `wait()`
  joins before the next save or on exit;
* restore(target=...) reshards onto the *current* mesh via device_put with the
  target shardings — the elastic-rescale path (tests/test_elastic.py).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from typing import Any, Optional

import numpy as np

import jax


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    dir: str
    keep_last: int = 3
    async_save: bool = True
    volume_mb: int = 256


def _paths_of(tree) -> list[str]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            for path, _ in flat]


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        os.makedirs(cfg.dir, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, extra: Optional[dict] = None):
        self.wait()
        # fetch to host synchronously (fully-addressable arrays on this host)
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]
        paths = _paths_of(tree)
        meta = {
            "step": int(step),
            "paths": paths,
            "shapes": [list(x.shape) for x in host_leaves],
            "dtypes": [str(x.dtype) for x in host_leaves],
            "extra": extra or {},
        }
        if self.cfg.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, host_leaves, meta)

    def _write(self, step: int, host_leaves, meta):
        try:
            final = os.path.join(self.cfg.dir, f"step_{step:08d}")
            tmp = final + ".tmp"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp, exist_ok=True)
            # chunk leaves into volumes by size
            budget = self.cfg.volume_mb * (1 << 20)
            vol, vol_bytes, vol_id, index = {}, 0, 0, []
            for i, arr in enumerate(host_leaves):
                vol[f"a{i}"] = arr
                index.append(vol_id)
                vol_bytes += arr.nbytes
                if vol_bytes >= budget:
                    np.savez(os.path.join(tmp, f"arrays_{vol_id:02d}.npz"), **vol)
                    vol, vol_bytes, vol_id = {}, 0, vol_id + 1
            if vol:
                np.savez(os.path.join(tmp, f"arrays_{vol_id:02d}.npz"), **vol)
            meta["volume_of"] = index
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            with open(os.path.join(tmp, "COMMITTED"), "w") as f:
                f.write("ok")
            shutil.rmtree(final, ignore_errors=True)
            os.replace(tmp, final)
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.cfg.keep_last]:
            shutil.rmtree(os.path.join(self.cfg.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.cfg.dir):
            d = os.path.join(self.cfg.dir, name)
            if name.startswith("step_") and os.path.exists(os.path.join(d, "COMMITTED")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target, step: Optional[int] = None,
                shardings=None) -> tuple[Any, int, dict]:
        """target: pytree prototype (structure source).  shardings: matching
        pytree of jax.sharding.Sharding to place leaves on the current mesh
        (elastic reshard), or None for plain host arrays→default device."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.cfg.dir}")
        d = os.path.join(self.cfg.dir, f"step_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        vols: dict[int, Any] = {}
        leaves = []
        for i, vol_id in enumerate(meta["volume_of"]):
            if vol_id not in vols:
                vols[vol_id] = np.load(os.path.join(d, f"arrays_{vol_id:02d}.npz"))
            leaves.append(vols[vol_id][f"a{i}"])
        _, treedef = jax.tree.flatten(target)
        proto_paths = _paths_of(target)
        if proto_paths != meta["paths"]:
            raise ValueError("checkpoint tree structure mismatch: "
                             f"{set(meta['paths']) ^ set(proto_paths)}")
        if shardings is not None:
            flat_sh = jax.tree.leaves(shardings, is_leaf=lambda x: x is None or
                                      isinstance(x, jax.sharding.Sharding))
            leaves = [jax.device_put(a, s) if s is not None else jax.numpy.asarray(a)
                      for a, s in zip(leaves, flat_sh)]
        else:
            leaves = [jax.numpy.asarray(a) for a in leaves]
        return jax.tree.unflatten(treedef, leaves), step, meta.get("extra", {})
