"""Case study I (paper §IV): LDPC min-sum decoding on the NoC.

    PYTHONPATH=src python examples/ldpc_decode.py

Reproduces the paper's setup: the N=7 projective-geometry (Fano plane) code,
bit/check node PEs wrapped and placed on a 4×4 mesh CONNECT-style NoC
(Fig. 9), including the 2-FPGA partition (the dotted arc) — and then the
scalable vectorized/kernel decoder with a BER-vs-SNR sweep.
"""
import numpy as np
import jax.numpy as jnp

from repro.apps import ldpc
from repro.core import NoCConfig, wrapper_overhead

rng = np.random.default_rng(0)
H = ldpc.fano_plane_H()
print("PG(2,2) Fano-plane H (paper's N=7, degree-3 code):")
print(H)

# --- Table I analog: per-node cost without/with the NoC wrapper -------------
g, _ = ldpc.build_ldpc_graph(H)
rows = wrapper_overhead(g, NoCConfig(flit_data_width=16, flit_buffer_depth=8))
print("\nTable-I analog (bytes instead of LUTs/registers):")
for r in rows[:4]:
    print(f"  {r['pe']:6s} raw={r['wo_wrapper_bytes']:4d}B "
          f"wrapped={r['with_wrapper_bytes']:4d}B overhead={r['overhead']:+.2f}x")

# --- Fig. 9: decode on a 4x4 mesh NoC, then cut across 2 FPGAs --------------
llr = ldpc.awgn_llr(np.zeros(7, np.int8), snr_db=2.0, rng=rng)
bits, post, stats = ldpc.decode_on_noc(H, llr, n_iters=10, topology="mesh",
                                       n_nodes=16)
print(f"\nsingle-FPGA 4x4 mesh: decoded={bits} "
      f"(rounds={stats.rounds}, flits={stats.flits})")
bits2, post2, st2 = ldpc.decode_on_noc(H, llr, 10, pods=[0] * 8 + [1] * 8)
assert np.array_equal(bits, bits2)
print(f"2-FPGA partition (dotted arc): identical decode; "
      f"cross-chip msgs={st2.cross_pod_msgs}, wire bytes={st2.cross_pod_wire_bytes}")

# --- scalable vectorized decoder + BER sweep ---------------------------------
print("\nBER sweep (vectorized min-sum kernel, 56-bit code, 200 frames/SNR):")
Hbig = ldpc.pg_ldpc_H(copies=8)
idx = ldpc.build_edge_index(Hbig)
for snr in (1.0, 2.0, 3.0, 4.0):
    errs_c = errs_u = 0
    n_frames = 200
    llrs = np.stack([ldpc.awgn_llr(np.zeros(56, np.int8), snr, rng)
                     for _ in range(n_frames)])
    dec, _ = ldpc.decode_minsum(idx, jnp.asarray(llrs), 12)
    errs_c = int(np.asarray(dec).sum())
    errs_u = int((llrs < 0).sum())
    print(f"  SNR {snr:3.1f} dB: uncoded BER {errs_u / llrs.size:.4f}  "
          f"coded BER {errs_c / llrs.size:.4f}")
