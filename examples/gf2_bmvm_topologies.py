"""Case study III (paper §VI): Williams sub-quadratic GF(2) BMVM — the
topology study (Table V) and the iterated-product speedup (Table IV).

    PYTHONPATH=src python examples/gf2_bmvm_topologies.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.apps import bmvm
from repro.core import compare

rng = np.random.default_rng(0)

# --- Table IV analog: speedup vs iterations (n=64, k=8, fold=2, 4 PEs) ------
cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)
A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
V = rng.integers(0, 2, (4, 64)).astype(np.uint8)
lut = bmvm.preprocess(A, cfg)
print(f"Table-IV analog: n=64 k=8 fold=2 ({cfg.n_pe} PEs), LUT "
      f"{tuple(lut.shape)} = {np.asarray(lut).nbytes / 1024:.0f} KiB")
# Pallas kernel validated in interpret mode (TPU is the target; on CPU the
# timed "hardware" path is the XLA-jitted LUT datapath the kernel implements)
assert np.array_equal(np.asarray(bmvm.iterate_kernel(lut, jnp.asarray(V), cfg, 3)),
                      bmvm.software_ref(A, V, 3))
print(f"{'r':>6s} {'software(us)':>14s} {'xla_lut(us)':>12s} {'speedup':>8s}")
for r in (1, 10, 100, 1000):
    t0 = time.monotonic()
    sw = bmvm.software_ref(A, V, r)
    t_sw = (time.monotonic() - t0) * 1e6
    it = jax.jit(lambda v: bmvm.iterate_kernel(lut, v, cfg, r, use_kernel=False))
    hw = np.asarray(it(jnp.asarray(V)))  # compile+run
    t0 = time.monotonic()
    hw = np.asarray(it(jnp.asarray(V)))
    t_hw = (time.monotonic() - t0) * 1e6
    assert np.array_equal(sw, hw)
    print(f"{r:6d} {t_sw:14.1f} {t_hw:12.1f} {t_sw / t_hw:8.2f}")

# --- Table V analog: topology comparison -------------------------------------
print("\nTable-V analog: one BMVM iteration routed over each topology")
print("(measured: round-by-round schedule simulation; model: alpha-beta)")
cfg2 = bmvm.BMVMConfig(n=256, k=4, fold=4)
A2 = rng.integers(0, 2, (256, 256)).astype(np.uint8)
v2 = rng.integers(0, 2, (256,)).astype(np.uint8)
lut2 = bmvm.preprocess(A2, cfg2)
print(f"{'topology':>9s} {'rounds':>7s} {'link_bytes':>11s} {'sim_ms':>8s} {'model_us(64PE)':>15s}")
model = {r["topology"]: r for r in compare(64, chunk_bytes=2 * cfg2.n_sub)}
for topo in ("ring", "mesh", "torus", "fattree"):
    t0 = time.monotonic()
    out, stats = bmvm.iterate_noc_sim(lut2, v2, cfg2, 2, topology=topo)
    dt = (time.monotonic() - t0) * 1e3
    assert np.array_equal(out.reshape(1, -1), bmvm.software_ref(A2, v2[None], 2))
    print(f"{topo:>9s} {stats.rounds:7d} {stats.link_bytes:11d} {dt:8.1f} "
          f"{model[topo]['model_time_us']:15.2f}")
print("=> cost/performance ordering ring < mesh < torus < fat-tree, as in the paper")
