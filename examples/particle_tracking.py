"""Case study II (paper §V): particle-filter object tracking over the NoC.

    PYTHONPATH=src python examples/particle_tracking.py
"""
import numpy as np

from repro.apps import particle_filter as pf
from repro.core import NoCConfig, wrapper_overhead

rng = np.random.default_rng(0)
cfg = pf.PFConfig(img=64, roi=16, n_particles=64, n_bins=16, seed=0)
frames, truth = pf.synth_video(cfg, 20, rng)
print(f"synthetic video: {frames.shape[0]} frames {frames.shape[1]}x{frames.shape[2]}, "
      f"{cfg.n_particles} particles, {cfg.n_bins}-bin histograms")

# direct (kernel) tracking
est = pf.track(frames, cfg)
err = np.linalg.norm(est - truth, axis=1)
print(f"kernel path:   mean err {err.mean():.2f}px  max {err.max():.2f}px")

# NoC realization: 4 particle-group PEs + root orchestrator (Figs. 10-12)
est2, stats = pf.track_on_noc(frames, cfg, n_pe=4, topology="mesh", n_nodes=8)
err2 = np.linalg.norm(est2 - truth, axis=1)
print(f"NoC (4 PEs):   mean err {err2.mean():.2f}px  "
      f"(flits={stats.flits}, rounds={stats.rounds})")
assert np.abs(est - est2).max() < 1e-2

# Table-III analog
g = pf.build_pf_graph(cfg, 4)
rows = wrapper_overhead(g, NoCConfig())
print("\nTable-III analog (per-PE bytes, wrapper = collector+distributor FIFOs):")
for r in rows:
    print(f"  {r['pe']:6s} raw={r['wo_wrapper_bytes']:6d}B "
          f"wrapped={r['with_wrapper_bytes']:6d}B")
print("\nper-frame estimates vs truth (first 5):")
for f in range(5):
    print(f"  frame {f}: est=({est[f][0]:5.1f},{est[f][1]:5.1f}) "
          f"truth=({truth[f][0]:5.1f},{truth[f][1]:5.1f})")
