"""Quickstart: the whole framework in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Express an app as a message-passing TaskGraph (phase-1).
2. Map it onto a packet-switched NoC topology and run it (phase-2, single pod).
3. Cut the NoC across two pods with quasi-SERDES endpoints — same results.
4. Trace a run: the event timeline aggregates back to the same NoCStats
   bit-exactly, and exports a Perfetto-loadable JSON.
5. Train a (reduced) llama3.2-1b for 100 steps with the LM generalization.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (NoCExecutor, PE, Port, TaskGraph, cut, make_topology,
                        place_greedy, QuasiSerdesConfig)

# --- 1. phase-1: the application as communicating processing elements -------
g = TaskGraph("pipeline")
g.add(PE("scale", lambda x: {"y": x * 2.0}, (Port("x", (8,)),), (Port("y", (8,)),)))
g.add(PE("shift", lambda y: {"z": y + 1.0}, (Port("y", (8,)),), (Port("z", (8,)),)))
g.add(PE("square", lambda z: {"o": z * z}, (Port("z", (8,)),), (Port("o", (8,)),)))
g.connect("scale.y", "shift.y")
g.connect("shift.z", "square.z")
inputs = {"scale.x": jnp.arange(8.0)}

# --- 2. map onto a 2x2 mesh NoC and execute ---------------------------------
topo = make_topology("mesh", 4)
placement = place_greedy(g, topo)
ex = NoCExecutor(g, topo, placement=placement)
out, stats = ex.run(inputs)
print("single-pod NoC result:", np.asarray(out["square.o"])[:4], "...")
print("  network stats:", stats.as_dict())

# --- 3. cut across two pods (quasi-SERDES on the cut links) -----------------
plan = cut(g, placement, pod_of_node=[0, 0, 1, 1],
           serdes_cfg=QuasiSerdesConfig(wire_bits=16, lanes=8, compress="bf16"))
ex2 = NoCExecutor(g, topo, placement=placement, plan=plan)
out2, stats2 = ex2.run(inputs)
assert np.allclose(out["square.o"], out2["square.o"], atol=1e-2)
print("2-pod partition identical; cross-pod msgs:", stats2.cross_pod_msgs,
      "wire bytes:", stats2.cross_pod_wire_bytes)

# --- 4. observe a run: tracing is opt-in and proof-carrying ------------------
from repro.telemetry import Tracer, chrome_trace, trace_stats

tr = Tracer()                       # bounded ring buffer of structured events
ex3 = NoCExecutor(g, topo, placement=placement, trace=tr)
out3, stats3 = ex3.run(inputs)
assert trace_stats(tr).as_dict() == stats3.as_dict()   # bit-exact round trip
doc = chrome_trace(tr)              # load traceEvents in ui.perfetto.dev
print("traced run:", len(tr), "events ->", len(doc["traceEvents"]),
      "Perfetto events; trace aggregation reproduces NoCStats bit-exactly")

# --- 5. the LM generalization: train a reduced llama for 100 steps ----------
print("\ntraining reduced llama3.2-1b (same framework, LM substrate):")
from repro.launch.train import run

losses = run(["--arch", "llama3.2-1b", "--smoke", "--steps", "100",
              "--batch", "8", "--seq", "32", "--lr", "2e-3", "--log-every", "25"])
print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}  (decreasing => learning)")
