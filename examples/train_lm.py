"""End-to-end LM training driver example: train a reduced MoE model (the
paper-technique flagship) for a few hundred steps with checkpoint/restart,
then serve it with batched requests.

    PYTHONPATH=src python examples/train_lm.py
"""
import tempfile

from repro.launch.serve import run as serve
from repro.launch.train import run as train

with tempfile.TemporaryDirectory() as ckpt:
    print("=== training reduced qwen3-moe (NoC token routing inside) ===")
    losses = train(["--arch", "qwen3-moe-235b-a22b", "--smoke",
                    "--steps", "150", "--batch", "8", "--seq", "64",
                    "--lr", "2e-3", "--ckpt", ckpt, "--ckpt-every", "50",
                    "--log-every", "25"])
    print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f}")

    print("\n=== simulated preemption: restart resumes from step 150 ===")
    losses2 = train(["--arch", "qwen3-moe-235b-a22b", "--smoke",
                     "--steps", "200", "--batch", "8", "--seq", "64",
                     "--lr", "2e-3", "--ckpt", ckpt, "--ckpt-every", "50",
                     "--log-every", "25"])

print("\n=== serving (batched requests, prefill + decode) ===")
out = serve(["--arch", "qwen3-moe-235b-a22b", "--smoke", "--requests", "8",
             "--batch", "4", "--prompt-len", "32", "--gen", "8"])
print("generated token matrix:", out.shape)
