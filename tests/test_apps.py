"""The paper's three case studies end to end (§IV, §V, §VI)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.apps import bmvm, ldpc, particle_filter as pf


# -- LDPC (§IV) ---------------------------------------------------------------

def test_fano_code_regular():
    H = ldpc.fano_plane_H()
    assert (H.sum(0) == 3).all() and (H.sum(1) == 3).all()


def test_ldpc_graph_matches_vectorized(rng):
    H = ldpc.fano_plane_H()
    idx = ldpc.build_edge_index(H)
    llr = ldpc.awgn_llr(np.zeros(7, np.int8), 3.0, rng)
    _, post_vec = ldpc.decode_minsum(idx, jnp.asarray(llr), 8)
    _, post_noc, stats = ldpc.decode_on_noc(H, llr, 8, topology="mesh", n_nodes=16)
    assert np.allclose(np.asarray(post_vec), post_noc, atol=1e-4)
    assert stats.rounds > 0


def test_ldpc_2pod_partition_identical(rng):
    """Paper Fig. 9 dotted arc: the 2-FPGA cut changes nothing numerically."""
    H = ldpc.fano_plane_H()
    llr = ldpc.awgn_llr(np.zeros(7, np.int8), 3.0, rng)
    _, post_a, _ = ldpc.decode_on_noc(H, llr, 6)
    _, post_b, st = ldpc.decode_on_noc(H, llr, 6, pods=[0] * 8 + [1] * 8)
    assert np.allclose(post_a, post_b, atol=1e-5)
    assert st.cross_pod_msgs > 0 and st.cross_pod_wire_bytes > 0


def test_ldpc_corrects_errors(rng):
    """Coded BER < uncoded BER over AWGN at moderate SNR."""
    H = ldpc.pg_ldpc_H(copies=8)          # 56 bits
    idx = ldpc.build_edge_index(H)
    n_trials, snr = 40, 3.0
    coded_err = uncoded_err = 0
    for _ in range(n_trials):
        llr = ldpc.awgn_llr(np.zeros(H.shape[1], np.int8), snr, rng)
        uncoded_err += int((llr < 0).sum())
        dec, _ = ldpc.decode_minsum(idx, jnp.asarray(llr), 12)
        coded_err += int(np.asarray(dec).sum())
    assert coded_err < uncoded_err, (coded_err, uncoded_err)


def test_ldpc_batched_decode(rng):
    H = ldpc.fano_plane_H()
    idx = ldpc.build_edge_index(H)
    llr = jnp.asarray(np.stack([ldpc.awgn_llr(np.zeros(7, np.int8), 4.0, rng)
                                for _ in range(5)]))
    dec, post = ldpc.decode_minsum(idx, llr, 10)
    assert dec.shape == (5, 7) and post.shape == (5, 7)


# -- particle filter (§V) ------------------------------------------------------

def test_pf_tracks(rng):
    cfg = pf.PFConfig(img=48, roi=12, n_particles=48, n_bins=12, seed=1)
    frames, truth = pf.synth_video(cfg, 10, rng)
    est = pf.track(frames, cfg)
    err = np.linalg.norm(est - truth, axis=1).mean()
    assert err < 6.0, err


def test_pf_noc_matches_direct(rng):
    cfg = pf.PFConfig(img=48, roi=12, n_particles=32, n_bins=12)
    frames, _ = pf.synth_video(cfg, 6, rng)
    est = pf.track(frames, cfg, use_kernel=False)
    est_noc, stats = pf.track_on_noc(frames, cfg, n_pe=4, n_nodes=8)
    assert np.abs(est - est_noc).max() < 1e-3
    assert stats.flits > 0


def test_pf_kernel_path_matches(rng):
    cfg = pf.PFConfig(img=48, roi=12, n_particles=32, n_bins=12)
    frames, _ = pf.synth_video(cfg, 5, rng)
    a = pf.track(frames, cfg, use_kernel=True)
    b = pf.track(frames, cfg, use_kernel=False)
    assert np.abs(a - b).max() < 1e-3


# -- BMVM (§VI) ----------------------------------------------------------------

@given(st.sampled_from([(32, 4, 1), (32, 4, 2), (64, 8, 2), (64, 4, 4)]),
       st.integers(1, 6), st.integers(0, 50))
@settings(max_examples=12, deadline=None)
def test_bmvm_kernel_iterated_vs_software(nkf, r, seed):
    n, k, f = nkf
    rng = np.random.default_rng(seed)
    cfg = bmvm.BMVMConfig(n=n, k=k, fold=f)
    A = rng.integers(0, 2, (n, n)).astype(np.uint8)
    V = rng.integers(0, 2, (2, n)).astype(np.uint8)
    lut = bmvm.preprocess(A, cfg)
    hw = np.asarray(bmvm.iterate_kernel(lut, jnp.asarray(V), cfg, r))
    sw = bmvm.software_ref(A, V, r)
    assert np.array_equal(hw, sw)


@pytest.mark.parametrize("topo", ["ring", "mesh", "torus", "fattree"])
def test_bmvm_noc_all_topologies(topo, rng):
    cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)
    A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
    v = rng.integers(0, 2, (64,)).astype(np.uint8)
    lut = bmvm.preprocess(A, cfg)
    out, stats = bmvm.iterate_noc_sim(lut, v, cfg, 3, topology=topo)
    sw = bmvm.software_ref(A, v[None], 3)
    assert np.array_equal(out.reshape(1, -1), sw)
    assert stats.rounds > 0


def test_bmvm_topology_cost_ordering(rng):
    """Table V: time/traffic ordering ring > mesh > torus > fattree."""
    cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)
    A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
    v = rng.integers(0, 2, (64,)).astype(np.uint8)
    lut = bmvm.preprocess(A, cfg)
    stats = {}
    for topo in ("ring", "mesh", "torus", "fattree"):
        _, st_ = bmvm.iterate_noc_sim(lut, v, cfg, 2, topology=topo)
        stats[topo] = st_
    assert (stats["ring"].rounds > stats["mesh"].rounds
            > stats["torus"].rounds > stats["fattree"].rounds)
    assert (stats["ring"].link_bytes > stats["mesh"].link_bytes
            > stats["torus"].link_bytes > stats["fattree"].link_bytes)


@pytest.mark.slow
def test_bmvm_spmd_matches_software():
    from tests.conftest import run_with_devices
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.apps import bmvm
rng = np.random.default_rng(0)
cfg = bmvm.BMVMConfig(n=64, k=8, fold=1)
A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
V = rng.integers(0, 2, (3, 64)).astype(np.uint8)
lut = bmvm.preprocess(A, cfg)
for topo in ("ring", "fattree"):
    out = np.asarray(bmvm.iterate_spmd(lut, jnp.asarray(V), cfg, 3, topology=topo))
    assert np.array_equal(out, bmvm.software_ref(A, V, 3)), topo
print("OK")
""", n_devices=8)
