"""Buffered wormhole switching property suite (the PR's headline deliverable).

What is proven, and by which test family:

* **routing validity** — dimension-ordered routes visit neighbors only, never
  revisit a node, are minimal on mesh/torus/fat-tree, and assign virtual
  channels that are monotone within a dimension (dateline discipline);
* **deadlock freedom** — adversarial workloads (all-to-all at buffer_depth=1,
  saturating hotspot, random multi-flit traffic on wrapped topologies) must
  *drain*; the simulator detects a true deadlock exactly (zero-move fixed
  point) and raises, so completion of these tests is the proof;
* **exactly-once delivery** — every payload byte arrives exactly once, in
  order, at the right node: `simulate_wormhole_cube` must equal the transpose
  oracle bit-for-bit, and the in-simulator assertions (dst match, in-order
  flit index) make the delivery path load-bearing;
* **arbitration fairness** — round-robin: N sources saturating one ejection
  port each deliver all their packets, and per-source service is balanced;
* **sim/analytic agreement** — the cycle simulator can never beat
  `switch_lower_bound`, meets it exactly in the contention-free and
  single-bottleneck regimes, and measured throughput never exceeds
  `saturation_rate`;
* **executor differential** — `mode="buffered"` == `sim` == `direct` on
  delivered values across 4 topologies, plus NoCStats static-field parity.

Property tests use the hypothesis shim in tests/conftest.py: with hypothesis
installed they are real property tests; without it they degrade to seeded
random cases instead of skipping.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (NoCConfig, NoCExecutor, PE, Port, TaskGraph,
                        make_topology)
from repro.core.switch import (Packet, SwitchConfig,
                               dor_route, link_loads, saturation_rate,
                               simulate_switch, simulate_wormhole_cube,
                               switch_lower_bound)
from repro.core.traffic import (TrafficConfig, generate_traffic,
                                traffic_matrix, transpose_partner)

TOPOLOGIES = ["ring", "mesh", "torus", "fattree"]


def _hops(topo, s, d):
    return len(dor_route(topo, s, d)[0]) - 1


# ---------------------------------------------------------------------------
# routing validity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo_name", TOPOLOGIES)
@pytest.mark.parametrize("n", [8, 16])
def test_dor_routes_valid(topo_name, n):
    topo = make_topology(topo_name, n)
    for s in range(n):
        for d in range(n):
            route, vcs = dor_route(topo, s, d)
            assert route[0] == s and route[-1] == d
            assert len(vcs) == len(route) - 1
            assert len(set(route)) == len(route), "route revisits a node"
            for a, b in zip(route, route[1:]):
                assert b in topo.neighbors(a), f"{a}->{b} not a link"
            assert all(0 <= v < 2 for v in vcs)
            if topo_name in ("mesh", "torus", "fattree"):
                assert len(route) - 1 == topo.hops(s, d), "not minimal"


def test_dor_vcs_monotone_within_dimension():
    """Dateline discipline: within one dimension the VC only steps up (0→1 at
    the wrap crossing), and it resets when routing turns from X to Y."""
    topo = make_topology("torus", 16)
    for s in range(16):
        for d in range(16):
            route, vcs = dor_route(topo, s, d)
            xs = [topo.coords(v)[0] for v in route]
            # X phase = hops where x changes; Y phase after
            for i in range(1, len(vcs)):
                same_dim = (xs[i] != xs[i + 1]) == (xs[i - 1] != xs[i])
                if same_dim:
                    assert vcs[i] >= vcs[i - 1], (s, d, vcs)


def test_wrapped_topologies_demand_escape_vcs():
    for name in ("ring", "torus"):
        with pytest.raises(ValueError, match="n_vcs"):
            simulate_switch(make_topology(name, 8), [Packet(0, 1, 1)],
                            SwitchConfig(n_vcs=1))


# ---------------------------------------------------------------------------
# single-packet latency: simulator == analytic bound == hops + flits
# ---------------------------------------------------------------------------

@given(st.sampled_from(TOPOLOGIES), st.integers(0, 15), st.integers(0, 15),
       st.integers(1, 9))
@settings(max_examples=40, deadline=None)
def test_single_packet_latency_exact(topo_name, src, dst, n_flits):
    """An uncontended packet's drain time is exactly hops + flits (one hop
    per cycle pipeline fill, then one flit per cycle) — simulator and
    analytic model agree with equality."""
    topo = make_topology(topo_name, 16)
    pkts = [Packet(src, dst, n_flits)]
    res = simulate_switch(topo, pkts)
    lb = switch_lower_bound(topo, pkts)
    assert res.stats.cycles == lb == _hops(topo, src, dst) + n_flits
    assert res.stats.packets == 1
    assert res.stats.flits == n_flits


# ---------------------------------------------------------------------------
# deadlock freedom + exactly-once delivery under adversarial load
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo_name", TOPOLOGIES)
@pytest.mark.parametrize("depth", [1, 2, 4])
def test_all_to_all_drains_and_delivers(topo_name, depth):
    """Saturating all-to-all at every buffer depth (depth=1 is the legal
    worst case) must drain — on ring/torus this exercises the dateline VCs,
    without which the unidirectional ring provably deadlocks — and deliver
    the exact transpose of the message cube."""
    topo = make_topology(topo_name, 16)
    rng = np.random.default_rng(depth)
    msgs = rng.integers(0, 256, (16, 16, 7), dtype=np.uint8)
    delivered, stats = simulate_wormhole_cube(
        topo, msgs, SwitchConfig(buffer_depth=depth))
    assert np.array_equal(delivered, msgs.swapaxes(0, 1))
    assert stats.cycles >= switch_lower_bound(
        topo, [Packet(s, d, 4) for s in range(16) for d in range(16)])


@given(st.sampled_from(TOPOLOGIES), st.integers(1, 3), st.integers(0, 10**6))
@settings(max_examples=24, deadline=None)
def test_random_traffic_delivers_exactly_once(topo_name, depth, seed):
    """Random multi-flit traffic with staggered injection times drains and
    delivers every payload byte exactly once (the simulator asserts in-order
    arrival at the correct node internally; here we check the payloads)."""
    topo = make_topology(topo_name, 16)
    rng = np.random.default_rng(seed)
    pkts = []
    for pid in range(60):
        s, d = int(rng.integers(16)), int(rng.integers(16))
        F = int(rng.integers(1, 6))
        pay = rng.integers(0, 256, F * 2, dtype=np.uint8)
        pkts.append(Packet(s, d, F, t_inject=int(rng.integers(0, 30)),
                           payload=pay))
    res = simulate_switch(topo, pkts, SwitchConfig(buffer_depth=depth))
    assert res.stats.packets == len(pkts)
    for p, got in zip(pkts, res.payloads):
        assert np.array_equal(got, p.payload), "payload corrupted"
    assert res.stats.cycles >= switch_lower_bound(topo, pkts)


@pytest.mark.parametrize("topo_name", ["ring", "torus"])
def test_depth1_wrapped_worst_case_drains(topo_name):
    """buffer_depth=1 on wrapped topologies under hotspot + uniform mix is
    the adversarial configuration for wormhole deadlock; dateline VCs must
    keep the channel dependency graph acyclic."""
    topo = make_topology(topo_name, 16)
    cfg = TrafficConfig(pattern="hotspot", injection_rate=0.8, n_packets=12,
                        hotspot=5, hotspot_frac=0.7, seed=7)
    pkts = generate_traffic(topo, cfg)
    res = simulate_switch(topo, pkts, SwitchConfig(buffer_depth=1))
    assert res.stats.packets == len(pkts)


def test_deadlock_detector_is_exact():
    """The detector fires only at a true zero-move fixed point: a workload
    with a long idle gap between injections must fast-forward, not raise."""
    topo = make_topology("mesh", 16)
    pkts = [Packet(0, 15, 3, t_inject=0), Packet(15, 0, 3, t_inject=500)]
    res = simulate_switch(topo, pkts)
    assert res.stats.packets == 2
    assert res.stats.cycles >= 500 + _hops(topo, 15, 0) + 3


# ---------------------------------------------------------------------------
# arbitration fairness
# ---------------------------------------------------------------------------

def test_round_robin_fairness_under_hotspot():
    """15 sources saturate one fat-tree ejection port.  Round-robin must
    (a) deliver everything, (b) balance service: with equal demand, per-source
    delivered-flit counts in any prefix of the ejection log may differ by at
    most one packet's worth of flits."""
    topo = make_topology("fattree", 16)
    F = 4
    pkts = []
    for s in range(1, 16):
        for k in range(3):
            pkts.append(Packet(s, 0, F, t_inject=0))
    res = simulate_switch(topo, pkts, record_ejections=True)
    assert res.stats.packets == len(pkts)
    # ejection port is the only bottleneck: the analytic ejection bound is
    # met exactly (1 flit/cycle once the pipeline fills)
    assert res.stats.cycles == switch_lower_bound(topo, pkts)
    # fairness: group ejected flits by source, compare completion spread
    per_src_last = {}
    for cyc, pid in res.ejections:
        per_src_last[pkts[pid].src] = cyc
    lasts = sorted(per_src_last.values())
    # no source finishes more than ~one round-trip of packets after another:
    # with RR service the last flits of all sources land within one packet
    # cascade of each other, not clustered source-by-source
    assert lasts[-1] - lasts[0] <= 15 * F, lasts
    # every source got service in the first half of the run
    first_half = {pkts[pid].src for cyc, pid in res.ejections
                  if cyc <= res.stats.cycles // 2}
    assert len(first_half) == 15, "some source starved in the first half"


def test_arbitration_counters_populated_under_contention():
    topo = make_topology("mesh", 16)
    pkts = generate_traffic(topo, TrafficConfig(
        pattern="transpose", injection_rate=0.9, n_packets=8, seed=3))
    res = simulate_switch(topo, pkts, SwitchConfig(buffer_depth=2))
    assert res.stats.stall_cycles > 0
    assert res.stats.max_queue >= 1
    assert res.stats.link_flits == sum(link_loads(topo, pkts).values())


# ---------------------------------------------------------------------------
# sim / analytic agreement
# ---------------------------------------------------------------------------

@given(st.sampled_from(TOPOLOGIES),
       st.sampled_from(["uniform", "hotspot", "transpose", "bursty"]),
       st.integers(1, 4), st.integers(0, 10**6))
@settings(max_examples=24, deadline=None)
def test_simulator_never_beats_lower_bound(topo_name, pattern, depth, seed):
    topo = make_topology(topo_name, 16)
    cfg = TrafficConfig(pattern=pattern, injection_rate=0.4, n_packets=10,
                        seed=seed)
    pkts = generate_traffic(topo, cfg)
    res = simulate_switch(topo, pkts, SwitchConfig(buffer_depth=depth))
    assert res.stats.cycles >= switch_lower_bound(topo, pkts)
    # accepted throughput can never exceed the analytic saturation rate
    thr = res.stats.throughput(topo.n_nodes)
    assert thr <= saturation_rate(topo, traffic_matrix(topo, cfg)) + 1e-9


def test_hotspot_meets_ejection_bound_exactly():
    """Single-bottleneck regime: on the crossbar the ejection port is the
    only contended resource, so the simulator must *equal* the analytic
    ejection bound — the two interpreters agree, not just order."""
    topo = make_topology("fattree", 16)
    pkts = [Packet(s, 0, 4, t_inject=0) for s in range(1, 16)]
    res = simulate_switch(topo, pkts)
    lb = switch_lower_bound(topo, pkts)
    assert res.stats.cycles == lb == 1 + 15 * 4   # first arrival + 60 flits


# ---------------------------------------------------------------------------
# traffic patterns
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo_name", TOPOLOGIES)
@pytest.mark.parametrize("pattern", ["uniform", "hotspot", "transpose", "bursty"])
def test_traffic_matrix_is_stochastic(topo_name, pattern):
    topo = make_topology(topo_name, 16)
    m = traffic_matrix(topo, TrafficConfig(pattern=pattern, hotspot=3))
    assert m.shape == (16, 16)
    assert np.allclose(m.sum(axis=1), 1.0)
    assert np.allclose(np.diag(m), 0.0)
    assert (m >= 0).all()


def test_hotspot_traffic_concentrates():
    topo = make_topology("mesh", 16)
    cfg = TrafficConfig(pattern="hotspot", hotspot=5, hotspot_frac=0.6,
                        n_packets=200, seed=0)
    pkts = generate_traffic(topo, cfg)
    frac = sum(p.dst == 5 for p in pkts if p.src != 5) / \
        sum(1 for p in pkts if p.src != 5)
    assert 0.5 < frac < 0.7, frac


def test_transpose_partner_is_transpose_on_square_mesh():
    topo = make_topology("mesh", 16)
    for v in range(16):
        x, y = topo.coords(v)
        p = transpose_partner(topo, v)
        if x != y:
            assert topo.coords(p) == (y, x)
        assert p != v


def test_bursty_traffic_clumps_injections():
    """Bursty injections arrive back-to-back in bursts of burst_len with the
    same long-run offered rate as uniform."""
    topo = make_topology("mesh", 16)
    cfg = TrafficConfig(pattern="bursty", burst_len=4, n_packets=16,
                        injection_rate=0.05, seed=0)
    pkts = [p for p in generate_traffic(topo, cfg) if p.src == 0]
    times = sorted(p.t_inject for p in pkts)
    # at least burst_len packets share each burst instant
    from collections import Counter
    counts = Counter(times)
    assert max(counts.values()) >= cfg.burst_len


def test_traffic_is_deterministic_in_seed():
    topo = make_topology("torus", 16)
    a = generate_traffic(topo, TrafficConfig(seed=11))
    b = generate_traffic(topo, TrafficConfig(seed=11))
    assert a == b
    c = generate_traffic(topo, TrafficConfig(seed=12))
    assert a != c


# ---------------------------------------------------------------------------
# executor differential: buffered == sim == direct
# ---------------------------------------------------------------------------

def _diamond_graph():
    g = TaskGraph("diamond")
    g.add(PE("src", lambda x: {"a": x + 1, "b": x * 3}, (Port("x", (4,)),),
             (Port("a", (4,)), Port("b", (4,)))))
    g.add(PE("l", lambda a: {"o": a * a}, (Port("a", (4,)),), (Port("o", (4,)),)))
    g.add(PE("r", lambda b: {"o": b - 2}, (Port("b", (4,)),), (Port("o", (4,)),)))
    g.add(PE("join", lambda l, r: {"out": l + r},
             (Port("l", (4,)), Port("r", (4,))), (Port("out", (4,)),)))
    g.connect("src.a", "l.a")
    g.connect("src.b", "r.b")
    g.connect("l.o", "join.l")
    g.connect("r.o", "join.r")
    return g


@pytest.mark.parametrize("topo_name", TOPOLOGIES)
def test_buffered_mode_bit_identical_diamond(topo_name):
    g = _diamond_graph()
    inp = {"src.x": jnp.arange(4.0)}
    ex = NoCExecutor(g, make_topology(topo_name, 6))
    direct = g.run(inp)
    sim, st_sim = ex.run(inp, mode="sim")
    buf, st_buf = ex.run(inp, mode="buffered")
    for k in direct:
        assert np.array_equal(np.asarray(buf[k]), np.asarray(direct[k]))
        assert np.array_equal(np.asarray(buf[k]), np.asarray(sim[k]))
    ds, db = st_sim.as_dict(), st_buf.as_dict()
    # static accounting identical; transport accounting mode-specific
    for f in ("waves", "payload_bytes", "flits", "cross_pod_msgs",
              "cross_pod_wire_bytes", "cross_pod_beats"):
        assert ds[f] == db[f], f
    assert db["switch_cycles"] == db["rounds"] > 0
    assert ds["switch_cycles"] == 0          # sim never touches the switch


@pytest.mark.parametrize("topo_name", TOPOLOGIES)
def test_buffered_apps_match_sim(topo_name):
    """The acceptance criterion: all three case-study apps deliver payloads
    bit-identical to mode="sim" on every topology."""
    from repro.apps import bmvm, ldpc, particle_filter as pf

    rng = np.random.default_rng(0)
    llr = ldpc.awgn_llr(np.zeros(7, np.int8), 3.0, rng)
    H = ldpc.fano_plane_H()
    b_s, i_s, _ = ldpc.decode_on_noc(H, llr, 5, topology=topo_name)
    b_b, i_b, st = ldpc.decode_on_noc(H, llr, 5, topology=topo_name,
                                      mode="buffered")
    assert np.array_equal(b_s, b_b) and np.array_equal(i_s, i_b)
    assert st.switch_cycles > 0

    rng = np.random.default_rng(0)
    bcfg = bmvm.BMVMConfig(n=64, k=8, fold=2)
    A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
    v = rng.integers(0, 2, (64,)).astype(np.uint8)
    lut = jnp.asarray(bmvm.preprocess(A, bcfg))
    o_s, _ = bmvm.iterate_noc_sim(lut, v, bcfg, 2, topology=topo_name)
    o_b, _ = bmvm.iterate_noc_sim(lut, v, bcfg, 2, topology=topo_name,
                                  mode="buffered")
    assert np.array_equal(np.asarray(o_s), np.asarray(o_b))
    assert np.array_equal(np.asarray(o_b).reshape(1, -1),
                          bmvm.software_ref(A, v[None], 2))

    pcfg = pf.PFConfig()
    frames, _ = pf.synth_video(pcfg, 2, np.random.default_rng(0))
    c_s, _ = pf.track_on_noc(frames, pcfg, topology=topo_name)
    c_b, _ = pf.track_on_noc(frames, pcfg, topology=topo_name, mode="buffered")
    assert np.array_equal(np.asarray(c_s), np.asarray(c_b))


@pytest.mark.parametrize("depth", [1, 2, 8])
def test_buffered_depth_sweep_same_values(depth):
    """Buffer depth changes timing, never values: the diamond outputs are
    identical at every depth, and deeper buffers never make the drain
    slower."""
    g = _diamond_graph()
    inp = {"src.x": jnp.arange(4.0)}
    ex = NoCExecutor(g, make_topology("torus", 6),
                     cfg=NoCConfig(switch_buffer_depth=depth))
    direct = g.run(inp)
    buf, st = ex.run(inp, mode="buffered")
    for k in direct:
        assert np.array_equal(np.asarray(buf[k]), np.asarray(direct[k]))
    assert st.switch_cycles > 0
    assert st.switch_max_queue <= depth


def test_buffered_mixed_dtype_and_batched():
    g = TaskGraph("mixed")
    g.add(PE("a", lambda x: {"i": (x * 2).astype(jnp.int32),
                             "u": (x + 1).astype(jnp.uint8)},
             (Port("x", (3,)),),
             (Port("i", (3,), np.int32), Port("u", (3,), np.uint8))))
    g.add(PE("b", lambda i: {"y": (i * i).astype(jnp.int32)},
             (Port("i", (3,), np.int32),), (Port("y", (3,), np.int32),)))
    g.add(PE("c", lambda u: {"z": (u + 3).astype(jnp.uint8)},
             (Port("u", (3,), np.uint8),), (Port("z", (3,), np.uint8),)))
    g.connect("a.i", "b.i")
    g.connect("a.u", "c.u")
    ex = NoCExecutor(g, make_topology("torus", 4))
    inp = {"a.x": jnp.arange(3.0)}
    direct = g.run(inp)
    buf, _ = ex.run(inp, mode="buffered")
    for k in direct:
        assert np.asarray(buf[k]).dtype == np.asarray(direct[k]).dtype
        assert np.array_equal(np.asarray(buf[k]), np.asarray(direct[k]))
    # batched: B sets ride the same wormhole packets as extra payload
    B = 3
    binp = {"a.x": np.stack([np.arange(3.0) * (b + 1) for b in range(B)])}
    bo, bst = ex.run_batch(binp, mode="buffered")
    so, sst = ex.run_batch(binp, mode="sim")
    for k in so:
        assert np.array_equal(np.asarray(bo[k]), np.asarray(so[k]))
    assert bst.payload_bytes == sst.payload_bytes == 3 * 15  # (12+3)B per set
    assert bst.switch_cycles > 0


# ---------------------------------------------------------------------------
# saturation sweep (slow): latency blows up past the analytic saturation rate
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_saturation_knee_matches_analytic_rate():
    """Offered load below saturation → near-flat latency and full acceptance;
    offered load past saturation → accepted throughput pins at the analytic
    rate (within discretization slack).  This is the table9 curve's shape."""
    topo = make_topology("mesh", 16)
    tcfg = TrafficConfig(pattern="uniform", n_packets=48, seed=0)
    sat = saturation_rate(topo, traffic_matrix(topo, tcfg))
    lat = {}
    for rate in (0.2 * sat, 2.0 * sat):
        cfg = TrafficConfig(pattern="uniform", injection_rate=rate,
                            n_packets=48, seed=0)
        pkts = generate_traffic(topo, cfg)
        res = simulate_switch(topo, pkts, SwitchConfig(buffer_depth=4))
        assert res.stats.packets == len(pkts)
        lat[rate] = res.stats.avg_latency
        thr = res.stats.throughput(16)
        assert thr <= sat + 1e-9
    assert lat[2.0 * sat] > 1.5 * lat[0.2 * sat], lat
