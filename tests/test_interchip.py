"""Inter-chip bridge subsystem: compiled route programs across pod cuts.

Four layers of guarantees:

* the **compiler** (`compile_bridges`) splits every schedule into per-pod
  programs + bridges that exactly partition the physical link traversals;
* the **simulator** (`simulate_bridged_program`) is bit-identical in delivery
  and ScheduleStats to the unpartitioned program — the cut is semantically
  transparent — while physically serializing every crossing buffer, and the
  **analytic** `bridge_program_stats` matches its BridgeStats exactly;
* the **executor** (`NoCExecutor(plan=...)`) keeps all three case-study apps
  bit-identical under any cut, with only the ``bridge_*`` NoCStats counters
  differing from the unpartitioned run;
* the **spmd lowering** (`run_bridged_program` over the ``(pod, node)`` mesh)
  equals partitioned sim in outputs *and* NoCStats — bridge counters included
  — for all 3 apps × topologies × pod cuts (subprocess, 8 fake CPU devices).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (BridgeConfig, NoCExecutor, PE, Port, TaskGraph,
                        bridge_program_stats, compile_bridges, compile_routes,
                        cut, make_topology, simulate_bridged_program,
                        simulate_route_program)
from repro.core.interchip import _walk_rounds
from repro.core.partition import PartitionPlan
from repro.core.serdes import QuasiSerdesConfig
from tests.conftest import run_with_devices

TOPOLOGIES = ["ring", "mesh", "torus", "fattree"]


def _plan_for(pods, serdes=None):
    return PartitionPlan({}, tuple(pods), (), (),
                         serdes or QuasiSerdesConfig(wire_bits=16, lanes=4))


def _pod_patterns(n, seed):
    rng = np.random.default_rng(seed)
    return [
        tuple(i // ((n + 1) // 2) for i in range(n)),   # blocked halves
        tuple(i % 2 for i in range(n)),                 # interleaved
        tuple(int(x) for x in rng.integers(0, 3, n)),   # random 3-pod
    ]


# ---------------------------------------------------------------------------
# compiler: per-pod split + bridge discovery
# ---------------------------------------------------------------------------

@given(st.sampled_from(TOPOLOGIES), st.sampled_from([4, 6, 8, 9, 12]),
       st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_compile_bridges_partitions_traversals(name, n, seed):
    """Every physical link traversal of every round lands in exactly one
    bucket — some pod's intra list or a bridge — and bridge endpoints always
    sit in different pods."""
    topo = make_topology(name, n)
    prog = compile_routes(topo)
    for pods in _pod_patterns(n, seed):
        bprog = compile_bridges(prog, _plan_for(pods))
        assert bprog.n_pods == max(pods) + 1
        for b in bprog.bridges:
            assert pods[b.src] != pods[b.dst]
            assert (b.src_pod, b.dst_pod) == (pods[b.src], pods[b.dst])
        for rnd, (den, pairs) in zip(bprog.rounds, _walk_rounds(prog)):
            assert rnd.den == den
            split = list(rnd.intra) + [
                (bprog.bridges[i].src, bprog.bridges[i].dst)
                for i in rnd.cross]
            assert sorted(split) == sorted(pairs)
        # per-pod programs: intra hops partition by source pod
        for rnd_idx, rnd in enumerate(bprog.rounds):
            by_pods = [pr for pp in bprog.pods for pr in pp.rounds[rnd_idx]]
            assert sorted(by_pods) == sorted(rnd.intra)
        for pp in bprog.pods:
            assert all(pods[i] == pp.pod for i in pp.nodes)
            assert all(bprog.bridges[i].src_pod == pp.pod for i in pp.egress)
            assert all(bprog.bridges[i].dst_pod == pp.pod for i in pp.ingress)


def test_compile_bridges_single_pod_has_no_bridges():
    for name in TOPOLOGIES:
        topo = make_topology(name, 6)
        bprog = compile_bridges(compile_routes(topo), _plan_for([0] * 6))
        assert bprog.bridges == ()
        assert all(not r.cross for r in bprog.rounds)


def test_compile_bridges_rejects_wrong_node_count():
    topo = make_topology("ring", 6)
    with pytest.raises(ValueError, match="plan covers"):
        compile_bridges(compile_routes(topo), _plan_for([0, 1]))


def test_transfer_hook_guards():
    """run_route_program must refuse transfer= misuse instead of silently
    executing cut links un-bridged: non-linearized calls and fused programs
    (whose crossbar has no hop moves) both raise."""
    from repro.core import run_route_program

    ring = compile_routes(make_topology("ring", 4))
    with pytest.raises(ValueError, match="linearized"):
        run_route_program(jnp.zeros((4, 2)), ring, transfer=lambda b, p: b)
    fat = compile_routes(make_topology("fattree", 4))
    with pytest.raises(ValueError, match="fused"):
        run_route_program(jnp.zeros((4, 2)), fat, axis_name="noc",
                          transfer=lambda b, p: b)


# ---------------------------------------------------------------------------
# simulator: the cut is semantically transparent; analytic stats are exact
# ---------------------------------------------------------------------------

@given(st.sampled_from(TOPOLOGIES), st.sampled_from([4, 6, 8, 9, 12]),
       st.integers(1, 9), st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_bridged_simulator_transparent_and_exact(name, n, c, seed):
    """Partitioned delivery == unpartitioned delivery (bit for bit), same
    rounds/link_bytes, and `bridge_program_stats` == the simulator's
    BridgeStats — per bridge included."""
    rng = np.random.default_rng(seed)
    topo = make_topology(name, n)
    prog = compile_routes(topo)
    msgs = rng.integers(0, 255, size=(n, n, c), dtype=np.uint8)
    d_ref, s_ref = simulate_route_program(prog, msgs)
    for pods in _pod_patterns(n, seed):
        bprog = compile_bridges(prog, _plan_for(pods),
                                BridgeConfig(serdes=QuasiSerdesConfig(
                                    wire_bits=16, lanes=2), fifo_depth=4))
        d, s, b = simulate_bridged_program(bprog, msgs)
        assert np.array_equal(d, d_ref)
        assert (s.rounds, s.link_bytes) == (s_ref.rounds, s_ref.link_bytes)
        b_ana = bridge_program_stats(bprog, msgs.nbytes)
        assert b_ana.as_dict() == b.as_dict()
        if any(pods[s_] != pods[d_] for s_, d_ in
               [(bl.src, bl.dst) for bl in bprog.bridges]):
            assert b.beats > 0 and b.wire_bytes > 0


def test_bridged_simulator_batched_matches_per_item():
    rng = np.random.default_rng(7)
    topo = make_topology("torus", 8)
    bprog = compile_bridges(compile_routes(topo), _plan_for([0] * 4 + [1] * 4))
    msgs = rng.integers(0, 255, (3, 8, 8, 5), dtype=np.uint8)
    db, sb, bb = simulate_bridged_program(bprog, msgs, batched=True)
    assert np.array_equal(db, msgs.swapaxes(1, 2))
    for i in range(3):
        di, _, _ = simulate_bridged_program(bprog, msgs[i])
        assert np.array_equal(db[i], di)
    # bytes scale with B through the actual payload
    _, s1, b1 = simulate_bridged_program(bprog, msgs[0])
    assert sb.rounds == s1.rounds
    assert sb.link_bytes == 3 * s1.link_bytes
    assert bb.wire_bytes == 3 * b1.wire_bytes


def test_non_uint8_payloads_roundtrip_through_bridges():
    """The wire framing is dtype-agnostic (operates on the byte view)."""
    rng = np.random.default_rng(3)
    topo = make_topology("mesh", 6)
    bprog = compile_bridges(compile_routes(topo), _plan_for([0, 1, 0, 1, 0, 1]))
    msgs = rng.normal(size=(6, 6, 3)).astype(np.float32)
    d, _, b = simulate_bridged_program(bprog, msgs)
    assert d.dtype == np.float32
    assert np.array_equal(d, msgs.swapaxes(0, 1))
    assert b.beats > 0


# ---------------------------------------------------------------------------
# bridge FIFO / bandwidth model
# ---------------------------------------------------------------------------

def test_bridge_fifo_model():
    """Framing, bandwidth and back-pressure semantics of one bridge:
    beats = padded words / lanes; total stall rounds are bandwidth-limited
    (depth-invariant — the serial link can only move ``lanes`` words/round);
    the FIFO depth bounds peak occupancy and shifts stalls between
    back-pressure during the schedule and the terminal drain."""
    topo = make_topology("ring", 4)
    prog = compile_routes(topo)
    pods = [0, 0, 1, 1]
    msgs = np.zeros((4, 4, 10), np.uint8)    # 40 B/traversal on each cut link
    serdes = QuasiSerdesConfig(wire_bits=16, lanes=2)
    stalls, peaks = [], []
    for depth in (1, 2, 16, 1024):
        bprog = compile_bridges(prog, _plan_for(pods),
                                BridgeConfig(serdes=serdes, fifo_depth=depth))
        _, _, b = simulate_bridged_program(bprog, msgs)
        stalls.append(b.stall_rounds)
        peaks.append(b.peak_fifo)
        assert b.peak_fifo <= depth          # the FIFO is physically bounded
        # one traversal = ceil(40/2) = 20 words, already a lanes multiple
        for pb in b.per_bridge.values():
            assert pb["wire_bytes"] % (serdes.lanes * serdes.beat_bytes) == 0
            assert pb["beats"] == pb["wire_bytes"] // serdes.beat_bytes // serdes.lanes
        assert b.peak_fifo >= 1
    # with depth >= lanes the serial link runs at full rate and stalls are
    # bandwidth-conserved: depth only moves them between back-pressure and
    # the terminal drain; a FIFO shallower than the lane count starves the
    # serializer and really does stall longer
    assert len(set(stalls[1:])) == 1 and stalls[1] > 0, stalls
    assert stalls[0] > stalls[1], stalls
    # deeper FIFOs absorb bigger bursts
    assert peaks == sorted(peaks) and peaks[0] < peaks[-1], peaks


def test_bridge_stats_scale_with_wire_width():
    """Halving the wire width doubles the beats (same bytes, narrower link)."""
    topo = make_topology("mesh", 8)
    prog = compile_routes(topo)
    pods = [0] * 4 + [1] * 4
    msgs = np.ones((8, 8, 16), np.uint8)
    beats = {}
    for wb in (8, 16, 32):
        bprog = compile_bridges(prog, _plan_for(pods),
                                BridgeConfig(serdes=QuasiSerdesConfig(
                                    wire_bits=wb, lanes=1)))
        beats[wb] = bridge_program_stats(bprog, msgs.nbytes).beats
    assert beats[8] == 2 * beats[16] == 4 * beats[32]


# ---------------------------------------------------------------------------
# executor: partitioned == unpartitioned for the apps (sim, no devices)
# ---------------------------------------------------------------------------

def _stats_equal_modulo_bridge(a, b):
    da, db = a.as_dict(), b.as_dict()
    for k in da:
        if not (k.startswith("bridge_") or k.startswith("cross_pod_")):
            assert da[k] == db[k], (k, da[k], db[k])


@pytest.mark.parametrize("topo_name", ["mesh", "ring"])
@pytest.mark.parametrize("pods", [[0] * 8 + [1] * 8,
                                  [0, 1] * 8,
                                  [0] * 4 + [1] * 4 + [2] * 4 + [3] * 4])
def test_ldpc_partitioned_identical(topo_name, pods):
    from repro.apps import ldpc

    rng = np.random.default_rng(0)
    H = ldpc.fano_plane_H()
    llr = ldpc.awgn_llr(np.zeros(7, np.int8), 3.0, rng)
    bits0, post0, st0 = ldpc.decode_on_noc(H, llr, 6, topology=topo_name)
    bits1, post1, st1 = ldpc.decode_on_noc(H, llr, 6, topology=topo_name,
                                           pods=pods)
    assert np.array_equal(bits1, bits0)
    assert np.array_equal(post1, post0)
    _stats_equal_modulo_bridge(st0, st1)
    assert st1.bridge_beats > 0 and st1.bridge_wire_bytes > 0


@pytest.mark.parametrize("topo_name", ["mesh", "fattree"])
@pytest.mark.parametrize("pods", [[0] * 4 + [1] * 4, [0, 1, 2, 3] * 2])
def test_bmvm_partitioned_identical(topo_name, pods):
    from repro.apps import bmvm

    rng = np.random.default_rng(0)
    cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)
    A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
    v = rng.integers(0, 2, (64,)).astype(np.uint8)
    lut = bmvm.preprocess(A, cfg)
    out0, st0 = bmvm.iterate_noc_sim(jnp.asarray(lut), v, cfg, 2,
                                     topology=topo_name)
    out1, st1 = bmvm.iterate_noc_sim(jnp.asarray(lut), v, cfg, 2,
                                     topology=topo_name, pods=pods)
    assert np.array_equal(out1, out0)
    assert np.array_equal(out1.reshape(1, -1), bmvm.software_ref(A, v[None], 2))
    _stats_equal_modulo_bridge(st0, st1)
    assert st1.bridge_beats > 0


@pytest.mark.parametrize("pods", [[0] * 4 + [1] * 4, [0, 1] * 4])
def test_particle_filter_partitioned_identical(pods):
    from repro.apps import particle_filter as pf

    rng = np.random.default_rng(3)
    cfg = pf.PFConfig(img=64, roi=16, n_particles=64, n_bins=16)
    frames, _ = pf.synth_video(cfg, 4, rng)
    c0, st0 = pf.track_on_noc(frames, cfg, n_pe=4, topology="torus", n_nodes=8)
    c1, st1 = pf.track_on_noc(frames, cfg, n_pe=4, topology="torus", n_nodes=8,
                              pods=pods)
    assert np.array_equal(c1, c0)
    _stats_equal_modulo_bridge(st0, st1)
    assert st1.bridge_beats > 0


def test_serdes_cfg_changes_bridge_counters_not_outputs():
    from repro.apps import ldpc

    rng = np.random.default_rng(1)
    H = ldpc.fano_plane_H()
    llr = ldpc.awgn_llr(np.zeros(7, np.int8), 3.0, rng)
    pods = [0] * 8 + [1] * 8
    outs, beats = [], []
    for wb, lanes in [(8, 1), (16, 4), (32, 8)]:
        bits, post, st = ldpc.decode_on_noc(
            H, llr, 5, pods=pods,
            serdes_cfg=QuasiSerdesConfig(wire_bits=wb, lanes=lanes))
        outs.append(post)
        beats.append(st.bridge_beats)
    assert np.array_equal(outs[0], outs[1]) and np.array_equal(outs[1], outs[2])
    assert len(set(beats)) == 3               # the link model really differs


def test_executor_sim_python_bridge_parity():
    """The seed loop's analytic bridge counters == the engine's simulated
    ones, field for field (the engine-vs-baseline contract extends to the
    partitioned mode)."""
    g = TaskGraph("pair")
    g.add(PE("a", lambda x: {"y": x * 2}, (Port("x", (5,)),), (Port("y", (5,)),)))
    g.add(PE("b", lambda y: {"z": y + 1}, (Port("y", (5,)),), (Port("z", (5,)),)))
    g.connect("a.y", "b.y")
    for topo_name in TOPOLOGIES:
        topo = make_topology(topo_name, 4)
        placement = {"a": 0, "b": 3}
        plan = cut(g, placement, [0, 0, 1, 1])
        ex = NoCExecutor(g, topo, placement=placement, plan=plan)
        inp = {"a.x": jnp.arange(5.0)}
        _, st_sim = ex.run(inp, mode="sim")
        _, st_leg = ex.run(inp, mode="sim_python")
        assert st_sim.as_dict() == st_leg.as_dict(), topo_name
        assert st_sim.bridge_beats > 0, topo_name


# ---------------------------------------------------------------------------
# co-optimizer + serdes-aware objective
# ---------------------------------------------------------------------------

def test_placement_cost_serdes_aware():
    from repro.core import pair_cut_weights, placement_cost
    from repro.core.serdes import link_wire_beats

    g = TaskGraph("pair")
    g.add(PE("a", lambda x: {"y": x * 2}, (Port("x", (100,)),),
             (Port("y", (100,)),)))
    g.add(PE("b", lambda y: {"z": y + 1}, (Port("y", (100,)),),
             (Port("z", (100,)),)))
    g.connect("a.y", "b.y")
    topo = make_topology("ring", 4)
    placement = {"a": 0, "b": 2}
    scfg = QuasiSerdesConfig(wire_bits=8, lanes=8)
    # same pod: plain bytes × hops
    assert placement_cost(g, topo, placement, [0, 0, 0, 0], scfg) == 400 * 2
    # across the cut: the edge costs its serialized wire beats, not bytes
    w = link_wire_beats((100,), np.float32, scfg)
    assert placement_cost(g, topo, placement, [0, 0, 1, 1], scfg) == w
    assert pair_cut_weights(g, scfg)[("a", "b")] == w
    # compression shrinks the cut weight the optimizer sees
    w_bf16 = placement_cost(g, topo, placement, [0, 0, 1, 1],
                            QuasiSerdesConfig(wire_bits=8, lanes=8,
                                              compress="bf16"))
    assert w_bf16 < w


def test_optimize_placement_agrees_with_placement_cost():
    """The annealer's serdes-aware objective IS placement_cost — a found
    placement never scores worse than the round-robin baseline under the
    same (pods, serdes) objective."""
    from repro.apps import ldpc
    from repro.core import optimize_placement, place_round_robin, placement_cost

    g, _ = ldpc.build_ldpc_graph(ldpc.fano_plane_H())
    topo = make_topology("mesh", 16)
    pods = [0] * 8 + [1] * 8
    scfg = QuasiSerdesConfig(wire_bits=8, lanes=8)
    opt = optimize_placement(g, topo, pod_of_node=pods, iters=1200, seed=0,
                             serdes_cfg=scfg)
    c_opt = placement_cost(g, topo, opt, pods, scfg)
    c_rr = placement_cost(g, topo, place_round_robin(g, topo), pods, scfg)
    assert c_opt <= c_rr


def test_optimize_pod_cut_co_optimizes():
    from repro.apps import ldpc
    from repro.core import (optimize_pod_cut, place_round_robin, placement_cost,
                            candidate_cuts)

    g, _ = ldpc.build_ldpc_graph(ldpc.fano_plane_H())
    topo = make_topology("mesh", 16)
    grid = [QuasiSerdesConfig(wire_bits=wb, lanes=ln)
            for wb in (8, 16) for ln in (1, 8)]
    plan, cost = optimize_pod_cut(g, topo, n_pods=2, serdes_grid=grid,
                                  iters=400, seed=0)
    assert plan.n_pods == 2 and plan.serdes_cfg in grid
    # beats the naive blocked cut + rr placement + default serdes
    naive = placement_cost(g, topo, place_round_robin(g, topo),
                           candidate_cuts(topo, 2)[0], QuasiSerdesConfig())
    assert cost <= naive
    # deterministic under the seed
    plan2, cost2 = optimize_pod_cut(g, topo, n_pods=2, serdes_grid=grid,
                                    iters=400, seed=0)
    assert cost2 == cost and plan2.pod_of_node == plan.pod_of_node
    # the chosen plan actually executes, bit-identically
    rng = np.random.default_rng(0)
    llr = ldpc.awgn_llr(np.zeros(7, np.int8), 4.0, rng)
    bits, _, stt = ldpc.decode_on_noc(ldpc.fano_plane_H(), llr, 8,
                                      pods=list(plan.pod_of_node),
                                      placement=plan.placement,
                                      serdes_cfg=plan.serdes_cfg)
    assert not bits.any()


def test_wire_framing_single_source():
    """Regression (framing unification): PartitionPlan.wire_bytes ==
    wire_beats × beat_bytes for every wire width, including odd payloads."""
    from repro.core import link_bytes_on_wire, link_wire_beats

    g = TaskGraph("odd")
    g.add(PE("a", lambda x: {"y": x}, (Port("x", (7,), np.uint8),),
             (Port("y", (7,), np.uint8),)))
    g.add(PE("b", lambda y: {"z": y}, (Port("y", (7,), np.uint8),),
             (Port("z", (7,), np.uint8),)))
    g.connect("a.y", "b.y")
    for wb in (8, 16, 32):
        for lanes in (1, 8):
            scfg = QuasiSerdesConfig(wire_bits=wb, lanes=lanes)
            plan = cut(g, {"a": 0, "b": 1}, [0, 1], scfg)
            assert plan.wire_bytes(g) == plan.wire_beats(g) * scfg.beat_bytes
            assert plan.wire_bytes(g) == link_bytes_on_wire((7,), np.uint8, scfg)
            assert plan.wire_beats(g) == link_wire_beats((7,), np.uint8, scfg)
            assert plan.wire_beats(g) % lanes == 0


def test_mesh_for_partition_axes():
    import jax

    from repro.core import mesh_for_partition

    topo = make_topology("ring", 4)
    if jax.device_count() >= 4:
        pytest.skip("single-device environment expected")
    with pytest.raises(RuntimeError, match="device_count"):
        mesh_for_partition(topo, _plan_for([0, 0, 1, 1]))


# ---------------------------------------------------------------------------
# spmd differential: partitioned sim == partitioned spmd (subprocess, 8 dev)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_spmd_bridged_route_program_matches_oracle():
    """run_bridged_program over blocked ('pod','node') and irregular cuts ==
    the transpose oracle, all topologies."""
    run_with_devices("""
import numpy as np, jax
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core import compile_bridges, compile_routes, make_topology
from repro.core.interchip import BridgeConfig, run_bridged_program
from repro.core.partition import PartitionPlan
from repro.core.serdes import QuasiSerdesConfig

rng = np.random.default_rng(1)
for name in ("ring", "mesh", "torus", "fattree"):
    for pods, axes in (((0,)*4 + (1,)*4, ("pod", "node")),
                       ((0, 1) * 4, None),
                       ((0, 0, 1, 2, 2, 1, 0, 1), None)):
        n = 8
        topo = make_topology(name, n)
        prog = compile_routes(topo)
        plan = PartitionPlan({}, pods, (), (), QuasiSerdesConfig(wire_bits=16, lanes=4))
        bprog = compile_bridges(prog, plan, BridgeConfig(serdes=plan.serdes_cfg))
        if axes:
            mesh = Mesh(np.array(jax.devices()[:n]).reshape(2, 4), axes)
        else:
            from repro.core import mesh_for_topology
            mesh = mesh_for_topology(topo)
        names = mesh.axis_names
        sizes = mesh.devices.shape
        def device_fn(local):
            x = local.reshape(local.shape[len(sizes):])
            return run_bridged_program(x, bprog, names).reshape(local.shape)
        cube = rng.integers(0, 255, (n, n, 7)).astype(np.uint8)
        sm = shard_map(device_fn, mesh=mesh, in_specs=P(*names),
                       out_specs=P(*names), check_vma=False)
        out = np.asarray(jax.jit(sm)(cube.reshape(tuple(sizes) + (n, 7))))
        assert np.array_equal(out.reshape(n, n, 7), cube.swapaxes(0, 1)), (name, pods)
print("OK")
""", n_devices=8)


@pytest.mark.slow
def test_spmd_partitioned_differential_ldpc():
    """LDPC × {mesh, ring, fattree} × {2-pod blocked, interleaved, 4-pod}:
    partitioned spmd == partitioned sim == unpartitioned sim, outputs and
    NoCStats (bridge counters included in the spmd==sim comparison)."""
    run_with_devices("""
import numpy as np
from repro.apps import ldpc

rng = np.random.default_rng(0)
H = ldpc.fano_plane_H()
llr = ldpc.awgn_llr(np.zeros(7, np.int8), 3.0, rng)
for topo in ("mesh", "ring", "fattree"):
    n = 8
    ref_bits, ref_post, ref_st = ldpc.decode_on_noc(H, llr, 5, topology=topo,
                                                    n_nodes=n)
    for pods in ([0]*4 + [1]*4, [0, 1]*4, [0, 0, 1, 1, 2, 2, 3, 3]):
        bits_s, post_s, st_s = ldpc.decode_on_noc(H, llr, 5, topology=topo,
                                                  n_nodes=n, pods=pods)
        bits_p, post_p, st_p = ldpc.decode_on_noc(H, llr, 5, topology=topo,
                                                  n_nodes=n, pods=pods,
                                                  mode="spmd")
        assert np.array_equal(bits_p, bits_s) and np.array_equal(post_p, post_s)
        assert np.array_equal(post_s, ref_post), (topo, pods)
        assert st_p.as_dict() == st_s.as_dict(), (topo, pods)
        d_ref, d_s = ref_st.as_dict(), st_s.as_dict()
        for k in d_ref:
            if not (k.startswith("bridge_") or k.startswith("cross_pod_")):
                assert d_ref[k] == d_s[k], (topo, pods, k)
print("OK")
""", n_devices=8)


@pytest.mark.slow
def test_spmd_partitioned_differential_bmvm():
    run_with_devices("""
import numpy as np, jax.numpy as jnp
from repro.apps import bmvm

rng = np.random.default_rng(0)
cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)
A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
v = rng.integers(0, 2, (64,)).astype(np.uint8)
lut = bmvm.preprocess(A, cfg)
sw = bmvm.software_ref(A, v[None], 3)
for topo in ("mesh", "torus"):
    for pods in ([0]*4 + [1]*4, [0, 1]*4):
        out_s, st_s = bmvm.iterate_noc_sim(jnp.asarray(lut), v, cfg, 3,
                                           topology=topo, pods=pods)
        out_p, st_p = bmvm.iterate_noc_sim(jnp.asarray(lut), v, cfg, 3,
                                           topology=topo, pods=pods,
                                           mode="spmd")
        assert np.array_equal(out_p, out_s), (topo, pods)
        assert np.array_equal(out_p.reshape(1, -1), sw), (topo, pods)
        assert st_p.as_dict() == st_s.as_dict(), (topo, pods)
print("OK")
""", n_devices=8)


@pytest.mark.slow
def test_spmd_partitioned_differential_particle_filter():
    run_with_devices("""
import numpy as np
from repro.apps import particle_filter as pf

rng = np.random.default_rng(3)
cfg = pf.PFConfig(img=64, roi=16, n_particles=64, n_bins=16)
frames, _ = pf.synth_video(cfg, 4, rng)
for topo in ("mesh", "fattree"):
    for pods in ([0]*4 + [1]*4, [0, 1]*4):
        c_s, st_s = pf.track_on_noc(frames, cfg, n_pe=4, topology=topo,
                                    n_nodes=8, pods=pods)
        c_p, st_p = pf.track_on_noc(frames, cfg, n_pe=4, topology=topo,
                                    n_nodes=8, pods=pods, mode="spmd")
        assert np.array_equal(c_p, c_s), (topo, pods)
        assert st_p.as_dict() == st_s.as_dict(), (topo, pods)
print("OK")
""", n_devices=8)
