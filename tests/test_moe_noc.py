"""MoE token dispatch over the compiled NoC route programs.

Four layers of guarantees:

* the **linearized route program** (`run_route_program(..., axis_name=)`) —
  the same compiled schedule the spmd executor runs, embedded in a single
  flat mesh axis — equals the transpose oracle for all 4 topologies;
* the **noc engine** matches the dense oracle on all 4 topologies and its
  flit/round/link-byte counters equal ``2 ×``
  :func:`repro.core.routing.route_program_stats` of the dispatched cube;
* **capacity semantics are unified**: gather and noc drop the *same tokens*
  under tight capacity (`dispatch_capacity` is the one shared budget, with
  ``NoCConfig.flit_buffer_depth`` as the knob and ``capacity_factor``
  derived);
* **fallbacks are loud**: engine demotions record a reason in
  `MoEDispatchStats.fallback` and warn.

Device tests run in a subprocess with fake CPU devices
(``XLA_FLAGS=--xla_force_host_platform_device_count``).
"""
import pytest

from tests.conftest import run_with_devices


# ---------------------------------------------------------------------------
# capacity helper (no devices)
# ---------------------------------------------------------------------------

def test_dispatch_capacity_one_formula():
    from repro.core.noc import NoCConfig
    from repro.models.moe import MoEConfig, dispatch_capacity, effective_capacity_factor

    c = MoEConfig(d_model=8, n_experts=8, top_k=2, d_ff=16, capacity_factor=1.0)
    # classic formula: max(8, tokens*k*cf/E), clamped to [1, tokens*k]
    assert dispatch_capacity(64, c) == 64 * 2 * 1.0 / 8
    assert dispatch_capacity(16, c) == 8       # legacy floor of 8 slots ...
    assert dispatch_capacity(2, c) == 2 * 2    # ... keeps tiny decode drop-free
    big = MoEConfig(8, 8, 2, 16, capacity_factor=100.0)
    assert dispatch_capacity(4, big) == 4 * 2    # ceiling: every packet fits
    # flit_buffer_depth IS the knob when a NoCConfig is attached
    cd = MoEConfig(8, 8, 2, 16, capacity_factor=1.0,
                   noc=NoCConfig(flit_buffer_depth=3))
    assert dispatch_capacity(16, cd) == 3
    # ... and capacity_factor is derived from it, not configured
    assert effective_capacity_factor(16, cd) == 3 * 8 / (16 * 2)
    assert effective_capacity_factor(64, c) == 1.0   # formula path round-trips
    assert effective_capacity_factor(16, c) == 2.0   # ... and reports the floor


def test_moe_stats_as_dict_fields():
    from repro.models.moe import MoEDispatchStats

    st = MoEDispatchStats(engine="noc", topology="ring", fallback=None,
                          capacity=4, capacity_factor=1.0, flits=10, rounds=6,
                          link_bytes=100, drops=2, peak_occupancy=5)
    d = st.as_dict()
    assert d["drops"] == 2 and d["rounds"] == 6 and d["topology"] == "ring"


# ---------------------------------------------------------------------------
# linearized route program == transpose oracle (device lowering)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_linearized_route_program_matches_oracle():
    """run_route_program over ONE flat mesh axis (the MoE's 'model' axis)
    equals the fused all_to_all transpose for every topology — the 2D
    programs' per-axis hops expand to full-axis ppermutes."""
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core import compile_routes, make_topology, run_route_program, transpose_oracle
for n in (4, 8):
    mesh = Mesh(np.array(jax.devices()[:n]), ("model",))
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n * n, 3)), jnp.float32)   # (n*n, chunk)
    for name in ("fattree", "ring", "mesh2d", "torus2d"):
        prog = compile_routes(make_topology(name, n))
        def routed(xl, prog=prog):
            return run_route_program(xl.reshape(n, -1), prog,
                                     axis_name="model").reshape(xl.shape)
        def oracle(xl):
            return transpose_oracle(xl.reshape(n, -1), "model").reshape(xl.shape)
        sm = lambda f: shard_map(f, mesh=mesh, in_specs=P("model"),
                                 out_specs=P("model"), check_vma=False)
        got = np.asarray(sm(routed)(x))
        want = np.asarray(sm(oracle)(x))
        assert np.array_equal(got, want), (name, n)
print("OK")
""", n_devices=8)


# ---------------------------------------------------------------------------
# noc engine: counters == 2x route_program_stats, all topologies
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_moe_noc_counters_match_route_program_stats():
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.routing import compile_routes, route_program_stats
from repro.core.noc import NoCConfig
from repro.core.topology import make_topology
from repro.launch.mesh import set_mesh
from repro.models import moe as M
from repro.models.layers import init_params
n = 8
mesh = Mesh(np.array(jax.devices()).reshape(1, n), ("data", "model"))
rng = np.random.default_rng(2)
E, d, k = 16, 32, 2
dense = M.MoEConfig(d, E, k, 48, impl="dense")
params = init_params(M.moe_specs(dense), jax.random.key(0))
x = jnp.asarray(rng.normal(size=(2, 32, d)), jnp.float32)
ncfg = NoCConfig(flit_buffer_depth=4)
with set_mesh(mesh):
    ref, _, _ = M.moe_apply(params, x, dense)
    for topo in ("fattree", "ring", "mesh2d", "torus2d"):
        c = M.MoEConfig(d, E, k, 48, impl="noc", noc_topology=topo, noc=ncfg)
        out, _, st = M.moe_apply(params, x, c)
        # exact counters: two trips (out + back) of the compiled program
        prog = compile_routes(make_topology(topo, n))
        msg = (E // n) * st.capacity * d * 4       # one (src,dst) token cube
        ss = route_program_stats(prog, n * n * msg)
        assert st.rounds == 2 * ss.rounds, topo
        assert st.link_bytes == 2 * ss.link_bytes, topo
        assert st.flits == 2 * n * n * ncfg.flits_for(msg), topo
        assert st.capacity == 4 and st.engine == "noc"
print("OK")
""", n_devices=8)


# ---------------------------------------------------------------------------
# unified capacity: gather == noc under tight capacity (drop parity)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_moe_capacity_parity_gather_vs_noc():
    """The same flit_buffer_depth drops the SAME tokens in both engines —
    outputs bit-close, drop counts and peak occupancy identical, across the
    whole depth sweep (including heavy-drop depth=1)."""
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.noc import NoCConfig
from repro.launch.mesh import set_mesh
from repro.models import moe as M
from repro.models.layers import init_params
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
rng = np.random.default_rng(1)
base = M.MoEConfig(d_model=32, n_experts=8, top_k=2, d_ff=64, impl="dense")
params = init_params(M.moe_specs(base), jax.random.key(0))
x = jnp.asarray(rng.normal(size=(4, 16, 32)), jnp.float32)
prev = None
with set_mesh(mesh):
    for depth in (1, 2, 4, 8):
        ncfg = NoCConfig(flit_buffer_depth=depth)
        og, _, sg = M.moe_apply(params, x, M.MoEConfig(
            32, 8, 2, 64, impl="gather", noc=ncfg))
        on, _, sn = M.moe_apply(params, x, M.MoEConfig(
            32, 8, 2, 64, impl="noc", noc_topology="torus2d", noc=ncfg))
        assert sg.capacity == sn.capacity == depth
        assert int(sg.drops) == int(sn.drops), depth
        assert int(sg.peak_occupancy) == int(sn.peak_occupancy), depth
        assert float(jnp.max(jnp.abs(og - on))) < 1e-5, depth
        if prev is not None:
            assert int(sn.drops) <= prev, "drops must shrink with depth"
        prev = int(sn.drops)
    assert prev == 0            # deep enough buffer => drop-free
print("OK")
""", n_devices=8)


# ---------------------------------------------------------------------------
# loud fallbacks
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_moe_fallback_reasons_and_warnings():
    run_with_devices("""
import warnings
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.launch.mesh import set_mesh
from repro.models import moe as M
from repro.models.layers import init_params
mesh = Mesh(np.array(jax.devices()).reshape(1, 4), ("data", "model"))
rng = np.random.default_rng(3)
x = jnp.asarray(rng.normal(size=(2, 8, 32)), jnp.float32)
with set_mesh(mesh):
    # trigger 1: n_experts % n_ranks != 0 -> dense_ref (perf cliff), warns
    bad = M.MoEConfig(32, 6, 2, 64, impl="gather")
    params = init_params(M.moe_specs(bad), jax.random.key(0))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        _, _, st = M.moe_apply(params, x, bad)
    assert st.engine == "dense" and "not divisible" in st.fallback
    assert any("not divisible" in str(m.message) for m in w)
    # trigger 2: decode-shaped input demotes noc -> gather, warns
    dec = M.MoEConfig(32, 8, 2, 64, impl="noc")
    params = init_params(M.moe_specs(dec), jax.random.key(0))
    xd = jnp.asarray(rng.normal(size=(2, 2, 32)), jnp.float32)  # S=2 < 4
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        _, _, st = M.moe_apply(params, xd, dec)
    assert st.engine == "gather" and "decode-shaped" in st.fallback
    assert any("decode-shaped" in str(m.message) for m in w)
# no mesh: expected single-host path — reason recorded, NO warning
c = M.MoEConfig(32, 8, 2, 64, impl="gather")
params = init_params(M.moe_specs(c), jax.random.key(0))
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    _, _, st = M.moe_apply(params, x, c)
assert st.engine == "dense" and "no mesh" in st.fallback
assert not any("moe_apply" in str(m.message) for m in w)
print("OK")
""", n_devices=4)


# ---------------------------------------------------------------------------
# stats thread through the full transformer stack
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_moe_stats_thread_through_transformer():
    """forward/loss surface moe_drops / moe_peak_occupancy from the stacked
    MoE layers (noc engine, tight capacity => nonzero drops in metrics)."""
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.configs import get_config
from repro.launch.mesh import set_mesh
from repro.models import transformer as T
from repro.models.layers import init_params
mesh = Mesh(np.array(jax.devices()).reshape(1, 4), ("data", "model"))
cfg = get_config("qwen3-moe-235b-a22b", smoke=True).replace(
    moe_impl="noc", moe_topology="mesh2d", moe_flit_buffer_depth=1)
params = init_params(T.abstract_params(cfg), jax.random.key(0))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)}
with set_mesh(mesh):
    loss, mets = T.loss(params, batch, cfg)
assert np.isfinite(float(loss))
assert "moe_drops" in mets and "moe_peak_occupancy" in mets
assert float(mets["moe_drops"]) > 0        # depth=1 must drop at T=32,k=2,E=8
assert float(mets["moe_peak_occupancy"]) > 0
print("OK")
""", n_devices=4)
