"""Data pipeline, optimizer, checkpoint, FT runner, elastic remesh."""
import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.data import DataConfig, ShardedTokenPipeline
from repro.data.pipeline import _synthesize
from repro.optim import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm, cosine_schedule
from repro.runtime import FTConfig, ResilientRunner, StepFailure, factor_mesh


# -- data ----------------------------------------------------------------------

@given(st.integers(0, 10_000), st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_data_deterministic_and_shard_disjoint(step, n_shards):
    cfg0 = DataConfig(vocab=211, seq_len=16, global_batch=8 * n_shards,
                      n_shards=n_shards, shard=0, seed=3)
    a = _synthesize(cfg0, step)
    b = _synthesize(cfg0, step)
    assert np.array_equal(a["tokens"], b["tokens"])        # pure function of step
    if n_shards > 1:
        cfg1 = DataConfig(vocab=211, seq_len=16, global_batch=8 * n_shards,
                          n_shards=n_shards, shard=1, seed=3)
        assert not np.array_equal(a["tokens"], _synthesize(cfg1, step)["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 211
    assert (a["labels"][:, :-1] == a["tokens"][:, 1:]).all()


def test_pipeline_resume_exactness():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=4)
    p = ShardedTokenPipeline(cfg)
    seen = [next(p) for _ in range(4)]
    state = p.state()
    assert state["step"] == 4
    p.close()
    p2 = ShardedTokenPipeline(cfg, start_step=2)
    assert np.array_equal(next(p2)["tokens"], seen[2]["tokens"])
    p2.close()


# -- optimizer -------------------------------------------------------------------

def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


@given(st.floats(0.1, 10.0), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_clip_by_global_norm(max_norm, seed):
    rng = np.random.default_rng(seed)
    g = {"a": jnp.asarray(rng.normal(size=(17,)) * 50, jnp.float32)}
    clipped, gn = clip_by_global_norm(g, max_norm)
    cn = float(jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree.leaves(clipped))))
    assert cn <= max_norm * 1.001
    if float(gn) <= max_norm:
        assert np.allclose(clipped["a"], g["a"])


def test_cosine_schedule_shape():
    lr = [float(cosine_schedule(jnp.int32(s), peak_lr=1e-3, warmup=10, total=100))
          for s in range(100)]
    assert lr[0] < lr[9] <= 1e-3 and abs(lr[10] - 1e-3) < 1e-9
    assert lr[-1] < lr[50] < lr[11]
    assert lr[-1] >= 1e-4 * 0.99                     # floor


# -- checkpoint -------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.int32)},
            "step": jnp.zeros((), jnp.int32)}


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(CheckpointConfig(d, keep_last=2, async_save=False))
        t = _tree()
        for s in (1, 2, 3):
            cm.save(s, t)
        assert cm.all_steps() == [2, 3]
        rt, step, _ = cm.restore(t)
        assert step == 3
        for x, y in zip(jax.tree.leaves(rt), jax.tree.leaves(t)):
            assert np.array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_async_and_extra():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(CheckpointConfig(d, async_save=True))
        cm.save(7, _tree(), extra={"data_step": 7})
        cm.wait()
        _, _, extra = cm.restore(_tree())
        assert extra["data_step"] == 7


def test_checkpoint_ignores_torn_writes():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(CheckpointConfig(d, async_save=False))
        cm.save(5, _tree())
        os.makedirs(os.path.join(d, "step_00000009"))  # no COMMITTED sentinel
        assert cm.latest_step() == 5
        rt, step, _ = cm.restore(_tree())
        assert step == 5


def test_checkpoint_structure_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(CheckpointConfig(d, async_save=False))
        cm.save(1, _tree())
        with pytest.raises(ValueError):
            cm.restore({"other": jnp.zeros(3)})


# -- FT runner ---------------------------------------------------------------------

def test_ft_failure_recovery_exact():
    """Injected failures + restore => byte-identical final state vs a clean run
    (deterministic data replay makes recovery exact)."""
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(CheckpointConfig(d, async_save=False))
        step_fn = lambda st, b: {"x": st["x"] * 1.01 + float(b["tokens"].sum() % 97)}
        pipe = ShardedTokenPipeline(DataConfig(vocab=50, seq_len=4, global_batch=2))
        fails = {3: 1, 7: 2}
        def inject(s):
            if fails.get(s, 0):
                fails[s] -= 1
                raise StepFailure(s)
        r = ResilientRunner(step_fn, cm, FTConfig(checkpoint_every=2, max_failures=4),
                            fail_injector=inject)
        state, stats = r.run({"x": 1.0}, pipe, 12)
        ref = {"x": 1.0}
        for s in range(12):
            ref = step_fn(ref, pipe.batch_at(s))
        pipe.close()
        assert stats.failures == 3 and stats.restores == 3
        assert abs(state["x"] - ref["x"]) < 1e-9


def test_ft_gives_up_after_max_failures():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(CheckpointConfig(d, async_save=False))
        pipe = ShardedTokenPipeline(DataConfig(vocab=50, seq_len=4, global_batch=2))
        def inject(s):
            raise StepFailure("always")
        r = ResilientRunner(lambda st, b: st, cm, FTConfig(max_failures=2),
                            fail_injector=inject)
        with pytest.raises(StepFailure):
            r.run({"x": 0.0}, pipe, 5)
        pipe.close()


def test_straggler_detection():
    import time
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(CheckpointConfig(d, async_save=False))
        pipe = ShardedTokenPipeline(DataConfig(vocab=50, seq_len=4, global_batch=2))
        slow_steps = set(range(10, 14))
        def step_fn(st, b):
            if step_fn.i in slow_steps:
                time.sleep(0.05)
            step_fn.i += 1
            return st
        step_fn.i = 0
        hits = []
        r = ResilientRunner(step_fn, cm,
                            FTConfig(checkpoint_every=100, straggler_factor=3.0,
                                     straggler_patience=2),
                            on_straggler=lambda s: hits.append(s))
        _, stats = r.run({"x": 0.0}, pipe, 20)
        pipe.close()
        assert stats.stragglers >= 2 and len(hits) >= 1


# -- elastic ---------------------------------------------------------------------

@given(st.integers(1, 512), st.sampled_from([0, 4, 16]))
@settings(max_examples=40, deadline=None)
def test_factor_mesh_valid(n, prefer):
    shape, axes = factor_mesh(n, prefer_model=prefer)
    tot = 1
    for s in shape:
        tot *= s
    assert tot == n and len(shape) == len(axes)
    if prefer and n % prefer == 0:
        assert shape[axes.index("model")] == prefer
