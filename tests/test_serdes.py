"""Quasi-SERDES endpoints: framing roundtrip, compression error bounds,
error feedback kills bias over repeated steps."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import QuasiSerdesConfig, compression_ratio, link_bytes_on_wire
from repro.core import serdes as S


@given(st.sampled_from([8, 16, 32]), st.sampled_from([1, 2, 4, 8]),
       st.integers(1, 300))
@settings(max_examples=40, deadline=None)
def test_lossless_roundtrip(wire_bits, lanes, n):
    cfg = QuasiSerdesConfig(wire_bits=wire_bits, lanes=lanes, compress="none")
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    meta = S.plan(x.shape, x.dtype, cfg)
    w, sw, _ = S.encode(x, cfg, meta)
    assert w.shape[0] == lanes                       # serialized into beats
    y = S.decode(w, sw, cfg, meta)
    assert np.array_equal(np.asarray(x), np.asarray(y))


@given(st.integers(2, 200), st.sampled_from([16, 64, 256]))
@settings(max_examples=30, deadline=None)
def test_int8_error_bound(n, block):
    """|x - deq(q(x))| <= max|block| / 127 per block (quantization step)."""
    cfg = QuasiSerdesConfig(compress="int8", block=block)
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)) * 3, jnp.float32)
    meta = S.plan(x.shape, x.dtype, cfg)
    w, sw, res = S.encode(x, cfg, meta)
    y = S.decode(w, sw, cfg, meta)
    xb = np.asarray(x)
    bound = np.abs(xb).max() / 127 + 1e-6
    assert np.abs(xb - np.asarray(y)).max() <= bound
    assert res is not None and res.shape == x.shape


def test_error_feedback_unbiased():
    """With error feedback, the *accumulated* transmitted signal tracks the
    accumulated true signal (residual stays bounded; no drift)."""
    cfg = QuasiSerdesConfig(compress="int8", block=32)
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    meta = S.plan(g.shape, g.dtype, cfg)
    res = None
    sent_sum = np.zeros(64)
    for step in range(50):
        w, sw, res = S.encode(g, cfg, meta, residual=res)
        sent_sum += np.asarray(S.decode(w, sw, cfg, meta))
    true_sum = np.asarray(g) * 50
    # without feedback the per-step bias would accumulate linearly
    assert np.abs(sent_sum - true_sum).max() <= np.abs(np.asarray(g)).max() / 127 * 3


def test_bf16_ratio_and_bound():
    cfg = QuasiSerdesConfig(compress="bf16")
    assert compression_ratio((1024,), jnp.float32, cfg) > 1.9
    x = jnp.linspace(-2, 2, 1024, dtype=jnp.float32)
    meta = S.plan(x.shape, x.dtype, cfg)
    w, sw, _ = S.encode(x, cfg, meta)
    y = S.decode(w, sw, cfg, meta)
    assert np.abs(np.asarray(x) - np.asarray(y)).max() < 0.02


def test_wire_accounting():
    cfg = QuasiSerdesConfig(wire_bits=16, lanes=8, compress="none")
    b = link_bytes_on_wire((100,), jnp.float32, cfg)
    assert b >= 400 and b % (8 * 2) == 0              # padded to lanes×wire
