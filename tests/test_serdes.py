"""Quasi-SERDES endpoints: framing roundtrip, compression error bounds,
error feedback kills bias over repeated steps."""
import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import QuasiSerdesConfig, compression_ratio, link_bytes_on_wire
from repro.core import serdes as S


@given(st.sampled_from([8, 16, 32]), st.sampled_from([1, 2, 4, 8]),
       st.integers(1, 300))
@settings(max_examples=40, deadline=None)
def test_lossless_roundtrip(wire_bits, lanes, n):
    cfg = QuasiSerdesConfig(wire_bits=wire_bits, lanes=lanes, compress="none")
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    meta = S.plan(x.shape, x.dtype, cfg)
    w, sw, _ = S.encode(x, cfg, meta)
    assert w.shape[0] == lanes                       # serialized into beats
    y = S.decode(w, sw, cfg, meta)
    assert np.array_equal(np.asarray(x), np.asarray(y))


@given(st.integers(2, 200), st.sampled_from([16, 64, 256]))
@settings(max_examples=30, deadline=None)
def test_int8_error_bound(n, block):
    """|x - deq(q(x))| <= max|block| / 127 per block (quantization step)."""
    cfg = QuasiSerdesConfig(compress="int8", block=block)
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)) * 3, jnp.float32)
    meta = S.plan(x.shape, x.dtype, cfg)
    w, sw, res = S.encode(x, cfg, meta)
    y = S.decode(w, sw, cfg, meta)
    xb = np.asarray(x)
    bound = np.abs(xb).max() / 127 + 1e-6
    assert np.abs(xb - np.asarray(y)).max() <= bound
    assert res is not None and res.shape == x.shape


def test_error_feedback_unbiased():
    """With error feedback, the *accumulated* transmitted signal tracks the
    accumulated true signal (residual stays bounded; no drift)."""
    cfg = QuasiSerdesConfig(compress="int8", block=32)
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    meta = S.plan(g.shape, g.dtype, cfg)
    res = None
    sent_sum = np.zeros(64)
    for step in range(50):
        w, sw, res = S.encode(g, cfg, meta, residual=res)
        sent_sum += np.asarray(S.decode(w, sw, cfg, meta))
    true_sum = np.asarray(g) * 50
    # without feedback the per-step bias would accumulate linearly
    assert np.abs(sent_sum - true_sum).max() <= np.abs(np.asarray(g)).max() / 127 * 3


def test_bf16_ratio_and_bound():
    cfg = QuasiSerdesConfig(compress="bf16")
    assert compression_ratio((1024,), jnp.float32, cfg) > 1.9
    x = jnp.linspace(-2, 2, 1024, dtype=jnp.float32)
    meta = S.plan(x.shape, x.dtype, cfg)
    w, sw, _ = S.encode(x, cfg, meta)
    y = S.decode(w, sw, cfg, meta)
    assert np.abs(np.asarray(x) - np.asarray(y)).max() < 0.02


def test_wire_accounting():
    cfg = QuasiSerdesConfig(wire_bits=16, lanes=8, compress="none")
    b = link_bytes_on_wire((100,), jnp.float32, cfg)
    assert b >= 400 and b % (8 * 2) == 0              # padded to lanes×wire


# ---------------------------------------------------------------------------
# edge cases: every wire_bits × lanes corner, odd payloads, meta agreement,
# multi-step error feedback on a *drifting* signal
# ---------------------------------------------------------------------------

@given(st.sampled_from([8, 16, 32]), st.sampled_from([1, 8]),
       st.sampled_from(["float32", "int32", "uint8", "int16"]),
       st.integers(1, 67))
@settings(max_examples=60, deadline=None)
def test_linkmeta_roundtrip_all_widths(wire_bits, lanes, dtype, n):
    """LinkMeta round trip across the full wire_bits × lanes grid and mixed
    dtypes, odd payload sizes included: both endpoints derive the same static
    plan, the frame pads to whole lanes, and decode is the exact inverse."""
    cfg = QuasiSerdesConfig(wire_bits=wire_bits, lanes=lanes, compress="none")
    rng = np.random.default_rng(n * wire_bits + lanes)
    if dtype.startswith("float"):
        x = jnp.asarray(rng.normal(size=(n,)), dtype)
    else:
        info = np.iinfo(dtype)
        x = jnp.asarray(rng.integers(info.min, info.max, size=(n,)), dtype)
    meta_tx = S.plan(x.shape, x.dtype, cfg)
    meta_rx = S.plan(x.shape, x.dtype, cfg)           # far endpoint, a priori
    assert meta_tx == meta_rx
    assert meta_tx.n_words % lanes == 0               # lanes-aligned padding
    assert meta_tx.n_words * cfg.beat_bytes >= x.nbytes
    w, sw, _ = S.encode(x, cfg, meta_tx)
    assert w.shape == (lanes, meta_tx.n_words // lanes)
    y = S.decode(w, sw, cfg, meta_rx)
    assert y.dtype == x.dtype
    assert np.array_equal(np.asarray(x), np.asarray(y))


@given(st.sampled_from([8, 16, 32]), st.sampled_from([1, 8]))
@settings(max_examples=12, deadline=None)
def test_odd_payload_padding_is_zero(wire_bits, lanes):
    """Padding bytes beyond the payload are zeros on the wire — deterministic
    frames (nothing leaks from adjacent memory) for odd-sized messages."""
    cfg = QuasiSerdesConfig(wire_bits=wire_bits, lanes=lanes, compress="none")
    x = jnp.asarray(np.full(5, 0xAB, np.uint8))       # 5 bytes, never aligned
    meta = S.plan(x.shape, x.dtype, cfg)
    w, _, _ = S.encode(x, cfg, meta)
    raw = np.asarray(w).view(np.uint8).reshape(-1)[:meta.n_words * cfg.beat_bytes]
    assert np.all(raw[:5] == 0xAB)
    assert np.all(raw[5:] == 0)


def test_int8_error_feedback_bounded_on_drifting_signal():
    """Error feedback over a multi-step loop with a *changing* signal: the
    residual stays bounded by one quantization step of the running signal
    (no accumulation), and the summed transmission tracks the summed truth."""
    cfg = QuasiSerdesConfig(compress="int8", block=32)
    rng = np.random.default_rng(1)
    meta = S.plan((64,), jnp.float32, cfg)
    res = None
    sent_sum = np.zeros(64)
    true_sum = np.zeros(64)
    max_abs = 0.0
    for step in range(80):
        g = jnp.asarray(rng.normal(size=(64,)) * (1 + 0.1 * step), jnp.float32)
        max_abs = max(max_abs, float(jnp.abs(g).max()))
        w, sw, res = S.encode(g, cfg, meta, residual=res)
        sent_sum += np.asarray(S.decode(w, sw, cfg, meta))
        true_sum += np.asarray(g)
        # boundedness every step, not just at the end
        assert np.abs(np.asarray(res)).max() <= max_abs / 127 * 2 + 1e-5, step
    assert np.abs(sent_sum - true_sum).max() <= max_abs / 127 * 3 + 1e-5


@given(st.sampled_from([1, 8]), st.integers(1, 50))
@settings(max_examples=20, deadline=None)
def test_int8_odd_sizes_roundtrip_bound(lanes, n):
    """int8 path with payloads that don't fill a block or a lane: scale words
    ride along and the error bound still holds."""
    cfg = QuasiSerdesConfig(wire_bits=16, lanes=lanes, compress="int8", block=16)
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)) * 2, jnp.float32)
    meta = S.plan(x.shape, x.dtype, cfg)
    assert meta.n_scale_words % lanes == 0
    w, sw, _ = S.encode(x, cfg, meta)
    y = S.decode(w, sw, cfg, meta)
    bound = float(jnp.abs(x).max()) / 127 + 1e-6
    assert np.abs(np.asarray(x) - np.asarray(y)).max() <= bound
