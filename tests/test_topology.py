"""Topology invariants (CONNECT analog), incl. the paper's Table-V ordering."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import compare, make_topology
from repro.core.topology import TOPOLOGIES


@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
@pytest.mark.parametrize("n", [4, 8, 12, 16, 64])
def test_link_symmetry_and_hops(name, n):
    t = make_topology(name, n)
    t.validate()
    for i in range(n):
        assert t.hops(i, i) == 0
        for j in t.neighbors(i):
            assert t.hops(i, j) == 1
    assert t.n_links() > 0
    assert t.bisection_links() >= 1


@given(st.sampled_from(sorted(TOPOLOGIES)), st.integers(2, 20))
@settings(max_examples=40, deadline=None)
def test_hops_symmetric(name, n):
    t = make_topology(name, n)
    for i in range(0, n, max(n // 4, 1)):
        for j in range(0, n, max(n // 3, 1)):
            assert t.hops(i, j) == t.hops(j, i)


def test_table5_ordering():
    """Paper Table V: ring < mesh < torus < fat-tree, for both rounds and
    the alpha-beta time model."""
    rows = {r["topology"]: r for r in compare(64, chunk_bytes=1024)}
    assert (rows["ring"]["rounds"] > rows["mesh"]["rounds"]
            > rows["torus"]["rounds"] > rows["fattree"]["rounds"])
    assert (rows["ring"]["model_time_us"] > rows["mesh"]["model_time_us"]
            > rows["torus"]["model_time_us"] > rows["fattree"]["model_time_us"])
    # cost ordering too (links = hardware cost proxy): fat tree pays bisection
    assert rows["fattree"]["bisection_links"] > rows["torus"]["bisection_links"]


def test_avg_hops_sane():
    assert make_topology("fattree", 16).avg_hops() == 1.0
    assert make_topology("ring", 16).avg_hops() > make_topology("torus", 16).avg_hops()
