"""Latency profiler + perf-regression gate (`repro.telemetry.profile` /
`regress`): decomposition exactness across the topology × app × mode grid,
critical-path identities against the analytic bounds, zero-overhead-off for
LatencyRecords, saved-trace round-trips, and both directions of the
regression diff."""
import json

import numpy as np
import pytest

from repro.telemetry import (Tracer, chrome_trace, enable_metrics,
                             disable_metrics, events_allocated,
                             events_from_chrome, profile_trace,
                             records_allocated, trace_stats)
from repro.telemetry.regress import compare_rows, metric_class

from test_telemetry import APPS, TOPOLOGIES, _bmvm_executor, _pods


# ---------------------------------------------------------------------------
# the keystone contract: exact decomposition + critical path, whole grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topology", TOPOLOGIES)
@pytest.mark.parametrize("app", list(APPS))
@pytest.mark.parametrize("variant", ["sim", "buffered", "bridged"])
def test_decomposition_exact_grid(topology, app, variant):
    run, n_nodes = APPS[app]
    mode = "buffered" if variant == "buffered" else "sim"
    pods = _pods(n_nodes) if variant == "bridged" else None
    tr = Tracer()
    run(topology, mode, pods, tr)
    assert tr.dropped == 0
    prof = profile_trace(tr)
    assert prof.records, "profiled run produced no latency records"
    # bit-exact decomposition for every record, attribution sums per wave
    prof.check_exact()
    for r in prof.records:
        assert r.serialization + r.hop + r.queueing + r.bridge == r.latency
        assert r.latency > 0 and r.hops >= 0 and r.flits >= 1
    # waves tile the logical clock: critical path length == final clock
    cp = prof.critical_path()
    assert cp.length == tr.clock
    assert cp.length == sum(w.dur for w in prof.waves)
    assert cp.gap == sum(w.gap for w in prof.waves)
    # attribution never invents or loses cycles
    assert sum(c for _, c in cp.attribution) == cp.gap
    if variant == "buffered":
        assert any(r.kind == "pkt" for r in prof.records)
        assert any(w.kind == "switch" for w in prof.waves)
    else:
        assert all(r.kind == "msg" for r in prof.records)
    if variant == "bridged":
        # bridge stalls land in the bridge component (a schedule wave is a
        # barrier: every message in it carries the wave's stall), and the
        # event-derived stall total matches the duration-derived one
        for w in prof.waves:
            recs = [r for r in prof.records if r.wave == w.index]
            assert all(r.bridge == w.bridge_stalls for r in recs)


def test_single_packet_meets_bound_exactly():
    """Uncontended packet: latency == critical path == switch_lower_bound
    == simulated cycles, with zero queueing — the acceptance identity."""
    from repro.core.switch import (Packet, SwitchConfig, simulate_switch,
                                   switch_lower_bound)
    from repro.core.topology import make_topology

    for topology, n in (("mesh", 16), ("ring", 8), ("torus", 16)):
        topo = make_topology(topology, n)
        pkts = [Packet(0, n - 1, 4, t_inject=0)]
        tr = Tracer()
        res = simulate_switch(topo, pkts, SwitchConfig(), tracer=tr)
        prof = profile_trace(tr).check_exact()
        assert len(prof.records) == 1
        r = prof.records[0]
        cp = prof.critical_path()
        bound = switch_lower_bound(topo, pkts, SwitchConfig())
        assert r.latency == cp.length == bound == res.stats.cycles
        assert r.queueing == 0 and r.bridge == 0
        assert r.serialization == 4 and r.hop == r.hops
        assert cp.gap == 0 and not cp.attribution


def test_contended_run_attributes_every_gap_cycle():
    """Two packets fighting for one link: the cycles above the bound are
    charged to named resources, and the sum is exact."""
    from repro.core.switch import (Packet, SwitchConfig, simulate_switch,
                                   switch_lower_bound)
    from repro.core.topology import make_topology

    topo = make_topology("mesh", 16)
    cfg = SwitchConfig()
    # same source row, same destination: they serialize through shared links
    pkts = [Packet(0, 15, 8, t_inject=0), Packet(1, 15, 8, t_inject=0),
            Packet(2, 15, 8, t_inject=0)]
    tr = Tracer()
    res = simulate_switch(topo, pkts, cfg, tracer=tr)
    prof = profile_trace(tr).check_exact()
    w = prof.waves[0]
    bound = switch_lower_bound(topo, pkts, cfg)
    assert res.stats.cycles > bound          # the cell is non-vacuous
    assert w.gap == res.stats.cycles - bound
    assert sum(c for _, c in w.attribution) == w.gap
    for resource, cycles in w.attribution:
        assert cycles > 0
        assert ("link" in resource or "bridge" in resource
                or "switch" in resource)
    # someone queued: at least one record has a nonzero queueing component
    assert any(r.queueing > 0 for r in prof.records)


def test_bridged_gap_names_the_gating_bridge():
    """A partitioned schedule run's bridge stalls are charged to the
    arg-max stalling bridge pair, src/dst named."""
    run, n_nodes = APPS["ldpc"]
    tr = Tracer()
    run("torus", "sim", _pods(n_nodes), tr)
    prof = profile_trace(tr).check_exact()
    stalls = sum(w.bridge_stalls for w in prof.waves)
    assert stalls > 0, "bridged ldpc run produced no bridge stalls"
    bridge_attr = [(res, c) for res, c in prof.critical_path().attribution
                   if res.startswith("bridge ")]
    assert bridge_attr
    assert sum(c for _, c in bridge_attr) == stalls
    # every record carries its wave's stall in the bridge component
    assert any(r.bridge > 0 for r in prof.records)


# ---------------------------------------------------------------------------
# zero overhead off
# ---------------------------------------------------------------------------

def test_profiling_disabled_allocates_no_records():
    ex, inputs, feedback = _bmvm_executor()
    ex.run_iterative(inputs, feedback, 1, mode="sim")   # warmup/compile
    ev0, rec0 = events_allocated(), records_allocated()
    ex.run_iterative(inputs, feedback, 2, mode="sim")
    ex.run_iterative(inputs, feedback, 2, mode="buffered")
    assert events_allocated() == ev0
    assert records_allocated() == rec0
    # tracing on but profiler not invoked: events yes, records still none
    ex2, inputs2, feedback2 = _bmvm_executor(trace=True)
    rec1 = records_allocated()
    ex2.run_iterative(inputs2, feedback2, 1, mode="buffered")
    assert events_allocated() > ev0
    assert records_allocated() == rec1
    # only profile_trace materializes records
    profile_trace(ex2.tracer)
    assert records_allocated() > rec1


def test_profile_strict_refuses_dropped_events():
    ex, inputs, feedback = _bmvm_executor(trace=Tracer(capacity=32))
    ex.run_iterative(inputs, feedback, 2, mode="buffered")
    assert ex.tracer.dropped > 0
    with pytest.raises(ValueError, match="dropped"):
        profile_trace(ex.tracer)
    prof = profile_trace(ex.tracer, strict=False)   # degrades, not crashes
    prof.check_exact()                              # survivors stay exact


# ---------------------------------------------------------------------------
# saved traces round-trip into the same profile
# ---------------------------------------------------------------------------

def test_events_from_chrome_roundtrip():
    run, _ = APPS["bmvm"]
    tr = Tracer()
    run("mesh", "buffered", None, tr)
    doc = json.loads(json.dumps(chrome_trace(tr)))   # through real JSON
    evs = events_from_chrome(doc)
    # trace_stats parity survives the round trip
    assert trace_stats(evs).as_dict() == trace_stats(tr).as_dict()
    p1 = profile_trace(tr).check_exact()
    p2 = profile_trace(evs).check_exact()
    assert [(r.src, r.dst, r.latency) for r in p1.records] == \
           [(r.src, r.dst, r.latency) for r in p2.records]
    assert p1.critical_path().length == p2.critical_path().length
    assert p1.links == p2.links


def test_report_and_flows_smoke():
    run, _ = APPS["pf"]
    tr = Tracer()
    run("mesh", "buffered", None, tr)
    prof = profile_trace(tr).check_exact()
    txt = prof.report()
    for needle in ("bottleneck report", "critical path", "serialization",
                   "queueing", "flows", "p99.9"):
        assert needle in txt
    flows = prof.flows()
    assert flows
    for st in flows.values():
        assert st["p50"] <= st["p99"] <= st["p999"] <= st["max"]
        assert st["count"] > 0


def test_publish_noc_latency_schema():
    reg = enable_metrics()
    try:
        run, _ = APPS["bmvm"]
        tr = Tracer()
        run("mesh", "buffered", None, tr)
        prof = profile_trace(tr)
        prof.publish(mode="buffered")
        hists = reg.histograms("noc.latency.")
        names = {h.name for h in hists.values()}
        assert {"noc.latency.total", "noc.latency.serialization",
                "noc.latency.hop", "noc.latency.queueing",
                "noc.latency.bridge", "noc.latency.flow"} <= names
        total = reg.histogram("noc.latency.total", mode="buffered")
        assert total.count == sum(r.n for r in prof.records)
        assert total.p50 <= total.p99 <= total.p999
        # component histogram sums reproduce the total sum exactly
        parts = sum(reg.histogram(f"noc.latency.{c}", mode="buffered").total
                    for c in ("serialization", "hop", "queueing", "bridge"))
        assert parts == total.total
        # prefix accessor filters: no serve/train histograms leak in
        assert all(k.startswith("noc.latency.") for k in hists)
    finally:
        disable_metrics()


def test_publish_noop_when_registry_disabled():
    disable_metrics()
    run, _ = APPS["pf"]
    tr = Tracer()
    run("mesh", "sim", None, tr)
    profile_trace(tr).publish()   # must not raise


# ---------------------------------------------------------------------------
# the regression gate: both directions
# ---------------------------------------------------------------------------

def test_metric_classes():
    assert metric_class("us", 1.0) == "timing"
    assert metric_class("seed_loop_us", 1.0) == "timing"
    assert metric_class("speedup_vs_sw", 1.0) == "timing"
    assert metric_class("tok_per_s", 1.0) == "timing"
    assert metric_class("cycles", 100) == "counter"
    assert metric_class("stalls", 100) == "counter"
    assert metric_class("deadlock_free", "True") == "text"


def test_compare_rows_counter_regression_and_improvement():
    base = [{"name": "t_x", "us": 10.0, "cycles": 100, "accepted": 0.5}]
    # unchanged: clean
    assert compare_rows(base, [dict(base[0])]) == []
    # counter worsens -> regression with named metric + delta
    worse = compare_rows(base, [{**base[0], "cycles": 120}])
    assert [f["verdict"] for f in worse] == ["regression"]
    assert worse[0]["metric"] == "cycles" and worse[0]["delta"] == "+20"
    # counter improves -> reported, not fatal
    better = compare_rows(base, [{**base[0], "cycles": 90}])
    assert [f["verdict"] for f in better] == ["improvement"]
    # higher-is-better direction: accepted dropping is the regression
    acc = compare_rows(base, [{**base[0], "accepted": 0.4}])
    assert acc[0]["metric"] == "accepted"
    assert acc[0]["verdict"] == "regression"


def test_compare_rows_timing_tolerance_and_gate():
    base = [{"name": "t_x", "us": 100.0}]
    within = compare_rows(base, [{"name": "t_x", "us": 110.0}],
                          timing_tol=0.25)
    assert within == []                       # +10% inside 25% tol
    beyond = compare_rows(base, [{"name": "t_x", "us": 200.0}],
                          timing_tol=0.25)
    assert beyond[0]["verdict"] == "regression"
    assert beyond[0]["cls"] == "timing"
    # gate off: timing can never fail
    assert compare_rows(base, [{"name": "t_x", "us": 900.0}],
                        gate_timing=False) == []


def test_compare_rows_text_and_presence():
    base = [{"name": "t_gate", "us": 0.0, "deadlock_free": "True"},
            {"name": "t_only_base", "us": 0.0, "cycles": 1}]
    flipped = compare_rows(base, [
        {"name": "t_gate", "us": 0.0, "deadlock_free": "False"}])
    verdicts = {(f["row"], f["metric"]): f["verdict"] for f in flipped}
    assert verdicts[("t_gate", "deadlock_free")] == "regression"
    assert verdicts[("t_only_base", "(row)")] == "regression"


def test_regress_main_gate_both_ways(tmp_path):
    """End-to-end `regress.main` on fabricated baselines + fresh rows:
    exit 0 when unchanged, exit 1 naming the metric on a slowdown."""
    from repro.telemetry import regress

    rows = [{"name": "table12_bmvm_buffered", "us": 5.0, "cycles": 100,
             "crit": 98}]
    baseline = {"table": "table12_profile", "fast": True,
                "meta": {"platform": "nowhere", "python": "0"},
                "rows": rows}
    bdir = tmp_path / "baselines"
    bdir.mkdir()
    (bdir / "BENCH_table12.json").write_text(json.dumps(baseline))
    fresh = tmp_path / "fresh.json"
    fresh.write_text(json.dumps({"table12_profile": rows}))
    argv = ["--tables", "table12_profile", "--baseline-dir", str(bdir),
            "--fresh-json", str(fresh)]
    assert regress.main(argv) == 0
    # injected slowdown: the gate goes red and names the metric
    slow = [dict(rows[0], cycles=150)]
    fresh.write_text(json.dumps({"table12_profile": slow}))
    report = tmp_path / "report.json"
    assert regress.main(argv + ["--json", str(report)]) == 1
    findings = json.loads(report.read_text())
    assert findings["failed"] is True
    f = findings["findings"][0]
    assert f["metric"] == "cycles" and f["verdict"] == "regression"
    # mismatched fast/full baselines are refused, not silently diffed
    baseline["fast"] = False
    (bdir / "BENCH_table12.json").write_text(json.dumps(baseline))
    assert regress.main(argv) == 1
