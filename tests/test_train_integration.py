"""End-to-end training integration on the host devices (1 CPU)."""
import numpy as np


def test_tiny_training_loss_decreases():
    from repro.launch.train import run
    losses = run(["--arch", "llama3.2-1b", "--smoke", "--steps", "120",
                  "--batch", "8", "--seq", "32", "--lr", "2e-3",
                  "--log-every", "60"])
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    assert last < first - 0.1, (first, last)


def test_serve_driver_batched_requests():
    from repro.launch.serve import run
    out = run(["--arch", "llama3.2-1b", "--smoke", "--requests", "6",
               "--batch", "3", "--prompt-len", "16", "--gen", "4"])
    assert out.shape == (6, 4)
    assert (out >= 0).all()


def test_train_with_checkpoint_restart(tmp_path):
    from repro.launch.train import run
    d = str(tmp_path / "ck")
    run(["--arch", "llama3.2-1b", "--smoke", "--steps", "6", "--batch", "4",
         "--seq", "16", "--ckpt", d, "--ckpt-every", "3"])
    # resume picks up from the checkpoint and continues to 10
    losses = run(["--arch", "llama3.2-1b", "--smoke", "--steps", "10",
                  "--batch", "4", "--seq", "16", "--ckpt", d, "--ckpt-every", "5"])
    assert len(losses) >= 4
