"""Telemetry subsystem: the trace↔stats parity contract (aggregating a full
trace reproduces the engine's NoCStats bit-exactly across the topology × app
× mode grid), zero overhead when tracing is off, exporter schema validity,
and the unified metrics registry naming shared by NoC engines and MoE."""
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

import jax.numpy as jnp

from repro.telemetry import (MOE_METRIC_NAMES, STEP_METRIC_NAMES,
                             MetricsRegistry, Tracer, chrome_trace,
                             disable_metrics, enable_metrics,
                             events_allocated, get_registry, heatmap,
                             link_utilization, trace_stats,
                             validate_chrome_trace, write_chrome_trace)

TOPOLOGIES = ["ring", "mesh", "torus", "fattree"]


def _pods(n):
    return [0] * (n // 2) + [1] * (n - n // 2)


def _run_bmvm(topology, mode, pods, tracer):
    from repro.apps import bmvm

    rng = np.random.default_rng(0)
    cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)
    A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
    v = rng.integers(0, 2, (64,)).astype(np.uint8)
    lut = bmvm.preprocess(A, cfg)
    _, stats = bmvm.iterate_noc_sim(lut, v, cfg, 2, topology=topology,
                                    mode=mode, pods=pods, tracer=tracer)
    return stats


def _run_ldpc(topology, mode, pods, tracer):
    from repro.apps import ldpc

    rng = np.random.default_rng(0)
    H = ldpc.fano_plane_H()
    llr = ldpc.awgn_llr(np.zeros(7, np.int8), 4.0, rng)
    _, _, stats = ldpc.decode_on_noc(H, llr, 2, topology=topology,
                                     n_nodes=16, mode=mode, pods=pods,
                                     tracer=tracer)
    return stats


def _run_pf(topology, mode, pods, tracer):
    from repro.apps import particle_filter as pf

    rng = np.random.default_rng(0)
    cfg = pf.PFConfig(img=48, roi=12, n_particles=32, n_bins=12)
    frames, _ = pf.synth_video(cfg, 3, rng)
    _, stats = pf.track_on_noc(frames, cfg, n_pe=4, topology=topology,
                               n_nodes=8, mode=mode, pods=pods, tracer=tracer)
    return stats


APPS = {"bmvm": (_run_bmvm, 8), "ldpc": (_run_ldpc, 16), "pf": (_run_pf, 8)}


# ---------------------------------------------------------------------------
# the keystone contract: trace aggregation == engine stats, bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topology", TOPOLOGIES)
@pytest.mark.parametrize("app", list(APPS))
@pytest.mark.parametrize("variant", ["sim", "buffered", "bridged"])
def test_trace_stats_parity_grid(topology, app, variant):
    run, n_nodes = APPS[app]
    mode = "buffered" if variant == "buffered" else "sim"
    pods = _pods(n_nodes) if variant == "bridged" else None
    tr = Tracer()
    stats = run(topology, mode, pods, tr)
    assert tr.dropped == 0
    agg = trace_stats(tr)
    # bit-exact: every field, including the high-water marks
    assert agg.as_dict() == stats.as_dict()
    if variant == "bridged":
        assert stats.cross_pod_msgs > 0          # the grid cell is non-vacuous
    if variant == "buffered":
        assert stats.switch_cycles > 0


def test_parity_sim_python():
    tr = Tracer()
    stats = _run_bmvm("mesh", "sim_python", None, tr)
    assert trace_stats(tr).as_dict() == stats.as_dict()
    tr2 = Tracer()
    stats2 = _run_bmvm("mesh", "sim_python", _pods(8), tr2)
    assert trace_stats(tr2).as_dict() == stats2.as_dict()
    assert stats2.bridge_peak_fifo > 0           # high-water mark exercised


def test_parity_high_water_marks_buffered_bridged():
    """Peak counters (max-merge fields) survive the round trip too."""
    tr = Tracer()
    stats = _run_ldpc("mesh", "buffered", _pods(16), tr)
    agg = trace_stats(tr)
    assert stats.switch_max_queue > 0
    assert agg.switch_max_queue == stats.switch_max_queue
    assert agg.bridge_peak_fifo == stats.bridge_peak_fifo
    assert agg.switch_peak_link_flits == stats.switch_peak_link_flits


# ---------------------------------------------------------------------------
# zero overhead when off
# ---------------------------------------------------------------------------

def _bmvm_executor(trace=None):
    from repro.apps import bmvm
    from repro.core import NoCExecutor, make_topology
    from repro.kernels import ref as kref

    rng = np.random.default_rng(0)
    cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)
    A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
    v = rng.integers(0, 2, (64,)).astype(np.uint8)
    lut = np.asarray(bmvm.preprocess(A, cfg))
    g, feedback = bmvm.build_bmvm_graph(lut, cfg)
    vw = np.asarray(kref.gf2_pack_vector(jnp.asarray(v), cfg.k), np.uint32)
    f = cfg.fold
    inputs = {f"lut{i}.v": vw[i * f:(i + 1) * f] for i in range(cfg.n_pe)}
    ex = NoCExecutor(g, make_topology("mesh", 2 * cfg.n_pe), trace=trace)
    return ex, inputs, feedback


def test_tracing_disabled_allocates_nothing():
    ex, inputs, feedback = _bmvm_executor()
    assert ex.tracer is None                     # default is off
    ex.run_iterative(inputs, feedback, 1, mode="sim")   # warmup/compile
    before = events_allocated()
    ex.run_iterative(inputs, feedback, 3, mode="sim")
    ex.run_iterative(inputs, feedback, 2, mode="buffered")
    ex.run_iterative(inputs, feedback, 2, mode="sim_python")
    assert events_allocated() == before


def test_tracing_disabled_timing_stable():
    """The off path is one pointer check per hook — this is a *stability
    canary* (two untraced runs agree), not the overhead gate (that is
    ``table11_observability``'s traced_over_untraced ratio).  Wall clock on
    a shared CI host swings, so the threshold is noise-aware: min-of-6 with
    15% relative + 1ms absolute slack."""
    ex, inputs, feedback = _bmvm_executor()
    ex.run_iterative(inputs, feedback, 2, mode="sim")   # warmup/compile

    def once():
        t0 = time.perf_counter()
        ex.run_iterative(inputs, feedback, 10, mode="sim")
        return time.perf_counter() - t0

    a = min(once() for _ in range(6))
    b = min(once() for _ in range(6))
    assert abs(a - b) <= 0.15 * max(a, b) + 1e-3


def test_tracer_true_constructs_fresh():
    ex, inputs, feedback = _bmvm_executor(trace=True)
    assert isinstance(ex.tracer, Tracer)
    ex.run_iterative(inputs, feedback, 1, mode="sim")
    assert len(ex.tracer) > 0


# ---------------------------------------------------------------------------
# ring buffer bound
# ---------------------------------------------------------------------------

def test_ring_buffer_bounded_and_strict():
    tr = Tracer(capacity=16)
    for i in range(100):
        tr.instant("msg", "node 0", ts=i, src=0, dst=1, bytes=4, flits=1, n=1)
    assert len(tr) == 16
    assert tr.emitted == 100
    assert tr.dropped == 84
    with pytest.raises(ValueError, match="dropped"):
        trace_stats(tr)
    # non-strict aggregation still folds what survived
    st = trace_stats(tr, strict=False)
    assert st.payload_bytes == 16 * 4


def test_tracer_rejects_bad_args():
    with pytest.raises(ValueError):
        Tracer(capacity=0)
    with pytest.raises(ValueError):
        Tracer(detail="everything")


@pytest.mark.parametrize("variant", ["buffered", "bridged",
                                     "buffered_bridged"])
def test_overflow_strict_refuses_engines(variant):
    """Ring-buffer overflow on the real engines (not just synthetic events):
    strict aggregation refuses loudly, ``strict=False`` degrades predictably
    — it returns counters folded from the surviving suffix, which can only
    undercount flow totals, never invent traffic."""
    emode = "sim" if variant == "bridged" else "buffered"
    pods = None if variant == "buffered" else _pods(16)
    tr = Tracer(capacity=24)
    stats = _run_ldpc("mesh", emode, pods, tr)
    assert tr.dropped > 0, "capacity=24 did not overflow: test is vacuous"
    assert len(tr) == 24
    with pytest.raises(ValueError, match="dropped"):
        trace_stats(tr)
    partial = trace_stats(tr, strict=False)
    # predictable degradation: what survives never exceeds the true totals
    assert partial.payload_bytes <= stats.payload_bytes
    assert partial.flits <= stats.flits
    assert partial.link_bytes <= stats.link_bytes
    assert partial.switch_max_queue <= stats.switch_max_queue
    assert partial.bridge_wire_bytes <= stats.bridge_wire_bytes
    # a complete trace of the same run still reproduces stats bit-exactly
    tr_full = Tracer()
    stats_full = _run_ldpc("mesh", emode, pods, tr_full)
    assert tr_full.dropped == 0
    assert trace_stats(tr_full).as_dict() == stats_full.as_dict()


# ---------------------------------------------------------------------------
# SwitchStats guards (satellite a)
# ---------------------------------------------------------------------------

def test_switch_stats_zero_delivered_guards():
    from repro.core.switch import SwitchStats, simulate_switch
    from repro.core.topology import make_topology

    st = SwitchStats()
    assert st.avg_latency == 0.0
    assert st.throughput(16) == 0.0
    st.cycles = 10
    assert st.throughput(0) == 0.0
    # a run with no packets delivers nothing and divides by nothing
    res = simulate_switch(make_topology("mesh", 16), [])
    assert res.stats.packets == 0
    assert res.stats.cycles == 0
    assert res.stats.avg_latency == 0.0
    assert res.stats.throughput(16) == 0.0


def test_switch_deadlock_event():
    from repro.core.switch import (DeadlockError, Packet, SwitchConfig,
                                   simulate_switch)
    from repro.core.topology import make_topology

    topo = make_topology("ring", 8)
    pkts = [Packet(s, (s + 4) % 8, 4, t_inject=0) for s in range(8)]
    tr = Tracer()
    with pytest.raises(DeadlockError):
        simulate_switch(topo, pkts,
                        SwitchConfig(buffer_depth=1, n_vcs=1,
                                     max_cycles=20_000),
                        verify=False, tracer=tr)
    names = [ev.name for ev in tr.events()]
    assert "deadlock" in names
    ev = next(e for e in tr.events() if e.name == "deadlock")
    assert ev.args["wedged"] > 0


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_chrome_trace_schema_roundtrip(tmp_path):
    tr = Tracer()
    stats = _run_bmvm("mesh", "sim", None, tr)
    doc = chrome_trace(tr)
    n = validate_chrome_trace(doc)
    assert n == len(doc["traceEvents"])
    path = tmp_path / "trace.json"
    write_chrome_trace(str(path), tr)
    loaded = json.loads(path.read_text())
    assert validate_chrome_trace(loaded) == n
    # link utilization is recoverable from the exported JSON alone and
    # matches both the in-memory trace and the engine's own counter
    util_t = link_utilization(tr)
    util_j = link_utilization(loaded)
    assert util_t == util_j
    assert sum(util_t.values()) == stats.link_bytes
    txt = heatmap(util_t)
    assert "total bytes" in txt and str(stats.link_bytes) in txt
    rows = heatmap(util_t, csv=True).splitlines()
    assert rows[0] == "src,dst,bytes"
    assert sum(int(r.split(",")[2]) for r in rows[1:]) == stats.link_bytes


@pytest.mark.parametrize("mode", ["sim", "buffered"])
def test_heatmap_includes_bridge_links(mode):
    """A partitioned run's hottest resource can be a bridge: the heatmap
    must show the serial links next to the router links — for the schedule
    transport AND the buffered switch (which emits its own per-link
    counters at the end of each run)."""
    n = 16
    pods = _pods(n)
    tr = Tracer()
    stats = _run_ldpc("mesh", mode, pods, tr)
    assert stats.cross_pod_msgs > 0
    assert stats.bridge_wire_bytes > 0
    util = link_utilization(tr)
    assert util, f"{mode} bridged run produced an empty heatmap"
    # bridge endpoints can coincide with router links, so split by event
    # kind: the bridge contribution is exactly the wire-byte counter
    util_routers = link_utilization(
        [ev for ev in tr.events() if ev.name != "bridge_tx"])
    total = sum(util.values())
    router_total = sum(util_routers.values())
    assert total - router_total == stats.bridge_wire_bytes
    # the router-link side is complete too (schedule rounds in sim, the
    # switch's end-of-run per-link counters in buffered)
    assert router_total == stats.link_bytes
    # both resource kinds render in the same matrix and CSV
    txt = heatmap(util)
    assert "total bytes" in txt and str(total) in txt
    csv_rows = heatmap(util, csv=True).splitlines()[1:]
    assert len(csv_rows) == len(util)
    assert sum(int(r.split(",")[2]) for r in csv_rows) == total


def test_chrome_trace_tamper_rejected():
    tr = Tracer()
    tr.span("wave", "noc", 0, 2, wave=0)
    doc = chrome_trace(tr)
    assert validate_chrome_trace(doc) == len(doc["traceEvents"])
    bad = json.loads(json.dumps(doc))
    bad["traceEvents"][-1]["ph"] = "Z"
    with pytest.raises(ValueError):
        validate_chrome_trace(bad)
    bad2 = json.loads(json.dumps(doc))
    del bad2["traceEvents"][-1]["ts"]
    with pytest.raises(ValueError):
        validate_chrome_trace(bad2)
    with pytest.raises(ValueError):
        validate_chrome_trace({"nope": []})


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_histogram_percentiles():
    reg = MetricsRegistry()
    h = reg.histogram("t.seconds")
    for v in np.linspace(0.001, 1.0, 1000):
        h.observe(float(v))
    assert h.count == 1000
    assert h.p50 == pytest.approx(0.5, rel=0.20)   # one log bucket (~19%)
    assert h.p99 <= h.p999 <= 1.0
    assert h.quantile(1.0) == 1.0
    assert h.vmin == pytest.approx(0.001)
    # underflow bucket: nonpositive values are counted, not crashed on
    h.observe(0.0)
    assert h.count == 1001


def test_histogram_empty_and_single_bucket_contract():
    """The empty-histogram contract: every quantile is 0.0, no division by
    zero anywhere; a single observation pins all quantiles to that value
    (single-bucket p99.9 edge case); out-of-range q raises."""
    reg = MetricsRegistry()
    h = reg.histogram("empty.series")
    for q in (0.0, 0.5, 0.99, 0.999, 1.0):
        assert h.quantile(q) == 0.0
    assert h.p50 == h.p99 == h.p999 == 0.0
    assert h.mean == 0.0
    # empty histograms snapshot/prometheus without crashing
    snap = reg.snapshot()["histograms"]["empty.series"]
    assert snap["count"] == 0 and snap["p99.9"] == 0.0
    assert "empty_series" in reg.prometheus()
    # single observation = single bucket: the clamp makes every quantile
    # (including p99.9, whose rank rounds up to the only sample) exact
    h.observe(7.3)
    assert h.p50 == h.p99 == h.p999 == 7.3
    assert h.quantile(0.0) == h.quantile(1.0) == 7.3
    # underflow-only histogram: quantiles report the underflow edge (0.0),
    # clamped inside the observed range; the true min stays on vmin
    h0 = reg.histogram("underflow.series")
    h0.observe(0.0)
    h0.observe(-4.0)
    assert h0.p50 == h0.p999 == 0.0
    assert h0.vmin == -4.0 and h0.vmax == 0.0
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError):
        h.quantile(-0.1)


def test_registry_snapshot_and_prometheus():
    reg = MetricsRegistry()
    reg.counter("noc.rounds", mode="sim").inc(5)
    reg.gauge("noc.peak", mode="sim").set_max(3)
    reg.gauge("noc.peak", mode="sim").set_max(2)   # max sticks
    with reg.timer("step.seconds"):
        pass
    snap = reg.snapshot()
    assert snap["counters"]["noc.rounds{mode=sim}"] == 5
    assert snap["gauges"]["noc.peak{mode=sim}"] == 3
    assert snap["histograms"]["step.seconds"]["count"] == 1
    assert "p99.9" in snap["histograms"]["step.seconds"]
    txt = reg.prometheus()
    assert "noc_rounds" in txt and 'mode="sim"' in txt
    assert 'quantile="0.999"' in txt
    with pytest.raises(ValueError):
        reg.counter("noc.rounds", mode="sim").inc(-1)


def test_engine_publishes_into_registry():
    reg = enable_metrics()
    try:
        stats = _run_bmvm("mesh", "sim", None, None)
        snap = reg.snapshot()
        key = "noc.rounds{mode=sim,topology=Mesh2D}"
        assert snap["counters"][key] == stats.rounds
        assert snap["counters"][
            "noc.link_bytes{mode=sim,topology=Mesh2D}"] == stats.link_bytes
    finally:
        disable_metrics()
    assert get_registry() is None


def test_moe_shares_naming_scheme():
    from repro.models.moe import MoEDispatchStats

    # the per-step metric names are a subset of the dispatch-stat names:
    # one schema, two publishers
    assert set(STEP_METRIC_NAMES.values()) <= set(MOE_METRIC_NAMES.values())
    reg = enable_metrics()
    try:
        st = MoEDispatchStats(engine="noc", topology="fattree", fallback=None,
                              capacity=8, capacity_factor=1.5, flits=64,
                              rounds=12, link_bytes=4096, drops=3,
                              peak_occupancy=7)
        st.publish()
        snap = reg.snapshot()
        assert snap["counters"]["noc.moe.drops{engine=noc,topology=fattree}"] == 3
        assert snap["gauges"][
            "noc.moe.peak_occupancy{engine=noc,topology=fattree}"] == 7
        # the train loop's step-metric dict lands on the same names
        reg.record_step_metrics({"moe_drops": 2, "moe_peak_occupancy": 9,
                                 "loss": 1.0})
        snap = reg.snapshot()
        assert snap["counters"]["noc.moe.drops"] == 2
        assert snap["gauges"]["noc.moe.peak_occupancy"] == 9
    finally:
        disable_metrics()


def test_publish_noop_when_disabled():
    from repro.models.moe import MoEDispatchStats

    disable_metrics()
    st = MoEDispatchStats(engine="noc", topology=None, fallback=None,
                          capacity=1, capacity_factor=1.0, flits=0, rounds=0,
                          link_bytes=0)
    st.publish()   # must not raise


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("app", ["bmvm", "ldpc", "pf"])
def test_cli_emits_valid_perfetto(app, tmp_path):
    out = tmp_path / f"{app}.json"
    repo = Path(__file__).parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(repo / "src") + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-m", "repro.telemetry", "--app", app,
         "--iters", "2", "--out", str(out)],
        capture_output=True, text=True, env=env, cwd=repo, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "parity OK (bit-exact)" in res.stdout
    doc = json.loads(out.read_text())
    assert validate_chrome_trace(doc) > 0
