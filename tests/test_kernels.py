"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


# -- GF(2) BMVM ---------------------------------------------------------------

@pytest.mark.parametrize("n,k,m", [(16, 4, 1), (32, 4, 3), (64, 8, 5),
                                   (128, 4, 2), (128, 8, 8)])
def test_gf2_bmvm_kernel_vs_oracles(n, k, m):
    rng = np.random.default_rng(n + k)
    A = jnp.asarray(rng.integers(0, 2, (n, n)), jnp.uint8)
    V = jnp.asarray(rng.integers(0, 2, (m, n)), jnp.uint8)
    lut = ref.gf2_preprocess(A, k)
    assert lut.shape == (n // k, 2 ** k, n // k)
    vw = ref.gf2_pack_vector(V, k).astype(jnp.uint32)
    out_k = ops.gf2_bmvm(lut, vw, use_kernel=True)
    out_r = ref.gf2_bmvm(lut, vw)
    assert np.array_equal(np.asarray(out_k), np.asarray(out_r))
    # against the direct O(n^2) oracle
    direct = ref.gf2_matmul_oracle(A, V)
    assert np.array_equal(np.asarray(ref.gf2_unpack_vector(out_k, k)),
                          np.asarray(direct))


@given(st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_gf2_linearity(seed):
    """A(u ⊕ v) == Au ⊕ Av — GF(2) linearity through the LUT datapath."""
    rng = np.random.default_rng(seed)
    n, k = 32, 4
    A = jnp.asarray(rng.integers(0, 2, (n, n)), jnp.uint8)
    u = jnp.asarray(rng.integers(0, 2, (1, n)), jnp.uint8)
    v = jnp.asarray(rng.integers(0, 2, (1, n)), jnp.uint8)
    lut = ref.gf2_preprocess(A, k)
    f = lambda x: np.asarray(ref.gf2_unpack_vector(
        ops.gf2_bmvm(lut, ref.gf2_pack_vector(x, k).astype(jnp.uint32)), k))
    assert np.array_equal(f(jnp.bitwise_xor(u, v)), f(u) ^ f(v))


def test_gf2_pack_unpack_roundtrip():
    rng = np.random.default_rng(7)
    v = jnp.asarray(rng.integers(0, 2, (3, 64)), jnp.uint8)
    for k in (4, 8, 16):
        w = ref.gf2_pack_vector(v, k)
        assert np.array_equal(np.asarray(ref.gf2_unpack_vector(w, k)), np.asarray(v))


# -- LDPC min-sum -------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 3), (7, 3), (64, 6), (200, 4), (1000, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_minsum_kernel_sweep(shape, dtype):
    rng = np.random.default_rng(shape[0])
    u = jnp.asarray(rng.normal(size=shape) * 4, dtype)
    a = ops.minsum_check(u, use_kernel=True)
    b = ref.minsum_check(u)
    assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@given(st.integers(2, 40), st.integers(2, 8), st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_minsum_properties(n, deg, seed):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(size=(n, deg)) * 3, jnp.float32)
    v = np.asarray(ref.minsum_check(u))
    un = np.asarray(u)
    for c in range(0, n, max(n // 3, 1)):
        for j in range(deg):
            others = np.delete(un[c], j)
            expect = np.prod(np.sign(others)) * np.abs(others).min()
            assert np.isclose(v[c, j], expect, atol=1e-5)


def test_minsum_positive_matches_paper_listing2():
    """Paper Listing 2: v1 = min(u2,u3) etc. for positive inputs, deg=3."""
    u = jnp.asarray([[1.0, 2.0, 3.0]])
    v = np.asarray(ref.minsum_check(u))[0]
    assert np.allclose(v, [2.0, 1.0, 1.0])


# -- particle filter histogram ------------------------------------------------

@pytest.mark.parametrize("N,px,B", [(1, 64, 8), (10, 300, 16), (33, 517, 12),
                                    (8, 1024, 32)])
def test_histogram_kernel_sweep(N, px, B):
    rng = np.random.default_rng(N + px)
    bins = jnp.asarray(rng.integers(0, B, (N, px)), jnp.int32)
    w = jnp.asarray(rng.uniform(0.1, 1, (px,)), jnp.float32)
    rh = jnp.asarray(rng.uniform(0, 1, (B,)), jnp.float32)
    rh = rh / rh.sum()
    h_k, bc_k = ops.particle_histogram(bins, w, rh, use_kernel=True)
    h_r = ref.weighted_histogram(bins, w, B)
    bc_r = ref.bhattacharyya(h_r, rh)
    assert np.allclose(np.asarray(h_k), np.asarray(h_r), atol=1e-5)
    assert np.allclose(np.asarray(bc_k), np.asarray(bc_r), atol=1e-5)


def test_histogram_normalized():
    rng = np.random.default_rng(3)
    bins = jnp.asarray(rng.integers(0, 8, (5, 100)), jnp.int32)
    w = jnp.ones((100,), jnp.float32)
    h, _ = ops.particle_histogram(bins, w, jnp.ones((8,)) / 8)
    assert np.allclose(np.asarray(h).sum(-1), 1.0, atol=1e-5)


# -- flash attention ----------------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,S,T,D", [
    (1, 4, 2, 64, 64, 32), (2, 2, 2, 37, 37, 16), (1, 8, 2, 16, 128, 32),
    (1, 2, 1, 128, 256, 64), (2, 4, 4, 100, 100, 8)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, Hq, Hkv, S, T, D, causal):
    rng = np.random.default_rng(S + T)
    q = jnp.asarray(rng.normal(size=(B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, T, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, T, D)), jnp.float32)
    o_k = ops.flash_attention(q, k, v, causal, True)
    o_r = ref.mha(q, k, v, causal=causal)
    assert np.allclose(np.asarray(o_k), np.asarray(o_r), atol=3e-5), \
        np.abs(np.asarray(o_k) - np.asarray(o_r)).max()


def test_flash_attention_bf16():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 2, 32, 16)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 32, 16)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 32, 16)), jnp.bfloat16)
    o_k = ops.flash_attention(q, k, v, True, True)
    o_r = ref.mha(q, k, v, causal=True)
    assert np.allclose(np.asarray(o_k, np.float32), np.asarray(o_r, np.float32),
                       atol=3e-2)


def test_flash_attention_grad_finite():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, 4, 16, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 16, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 16, 8)), jnp.float32)
    g = jax.grad(lambda q_: ops.flash_attention(q_, k, v, True, False).sum())(q)
    assert bool(jnp.all(jnp.isfinite(g)))
