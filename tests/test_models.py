"""Per-architecture smoke tests (REDUCED configs, 1 CPU device, per spec):
one forward + one train step asserting output shapes and no NaNs; plus the
serve-path consistency and chunked-recurrence oracles."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_config
from repro.models import transformer as T
from repro.models.layers import init_params


def _batch_for(cfg, B, S, rng, labels=True):
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if labels:
        b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(rng.normal(size=(B, cfg.enc_seq, cfg.d_frontend)),
                                  jnp.float32)
    if cfg.family == "vlm":
        b["patches"] = jnp.asarray(rng.normal(size=(B, cfg.n_patches, cfg.d_frontend)),
                                   jnp.float32)
    return b


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_and_train_step(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = init_params(T.abstract_params(cfg), jax.random.key(0))
    B, S = 2, 16
    batch = _batch_for(cfg, B, S, rng)
    logits, aux, _, _ = T.forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    # one full train step: loss + grads finite, params change
    loss, mets = T.loss(params, batch, cfg)
    grads = jax.grad(lambda p: T.loss(p, batch, cfg)[0])(params)
    gn = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(loss)) and bool(jnp.isfinite(gn)) and float(gn) > 0
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    new_params, _, _ = adamw_update(params, grads, adamw_init(params), AdamWConfig())
    deltas = [float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(params))]
    assert max(deltas) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_full_config_registered(arch):
    cfg = get_config(arch)
    assert cfg.n_layers % len(cfg.pattern) == 0
    n = cfg.param_count()
    assert n > 1e8, f"{arch}: {n:,} params looks too small for the full config"
    if cfg.n_experts:
        assert cfg.active_param_count() < n


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_serve_matches_forward(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = init_params(T.abstract_params(cfg), jax.random.key(1))
    B, S = 2, 12
    batch = _batch_for(cfg, B, S, rng, labels=False)
    logits_full, _, _, _ = T.forward(params, batch, cfg)
    extra = cfg.n_patches if cfg.family == "vlm" else 0
    cache = T.init_cache(cfg, B, S + extra + 2)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :8]
    lg, cache = T.prefill(params, pre, cfg, cache)
    errs = [float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, 7])))]
    for t in range(8, S):
        sb = {"tokens": batch["tokens"][:, t:t + 1]}
        if cfg.family == "encdec":
            sb["frames"] = batch["frames"]
        lg, cache = T.decode_step(params, sb, cfg, cache)
        errs.append(float(jnp.max(jnp.abs(lg - logits_full[:, t]))))
    scale = float(jnp.max(jnp.abs(logits_full)))
    assert max(errs) < 2e-3 * max(scale, 1.0), (arch, max(errs))


def test_mamba_chunked_equals_sequential(rng):
    from repro.models import ssm
    c = ssm.MambaConfig(32, d_state=8, d_conv=4, expand=2, chunk=8)
    p = init_params(ssm.mamba_specs(c), jax.random.key(2))
    x = jnp.asarray(rng.normal(size=(2, 21, 32)), jnp.float32)
    a, _ = ssm.mamba_apply(p, x, c)
    b = ssm.mamba_scan_ref(p, x, c)
    assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_mlstm_chunked_equals_sequential(rng):
    from repro.models import xlstm
    c = xlstm.XLSTMConfig(32, 4, chunk=8)
    p = init_params(xlstm.mlstm_specs(c), jax.random.key(3))
    x = jnp.asarray(rng.normal(size=(2, 21, 32)), jnp.float32)
    a, _ = xlstm.mlstm_apply(p, x, c)
    b = xlstm.mlstm_seq_ref(p, x, c)
    assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_mlstm_no_overflow_with_extreme_gates(rng):
    """Stabilized exponential gating: no NaN/inf even with huge gate logits."""
    from repro.models import xlstm
    c = xlstm.XLSTMConfig(16, 2, chunk=4)
    p = init_params(xlstm.mlstm_specs(c), jax.random.key(4))
    p = jax.tree.map(lambda a: a * 30 if a.ndim >= 2 else a, p)
    x = jnp.asarray(rng.normal(size=(1, 13, 16)) * 10, jnp.float32)
    out, _ = xlstm.mlstm_apply(p, x, c)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_long_context_support_flags():
    from repro.configs import SHAPES, cell_supported, long_context_ok
    assert long_context_ok(get_config("xlstm-350m"))
    assert long_context_ok(get_config("jamba-v0.1-52b"))
    assert not long_context_ok(get_config("llama3.2-1b"))
    ok, why = cell_supported(get_config("gemma-7b"), SHAPES["long_500k"])
    assert not ok and why
