import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# ---------------------------------------------------------------------------
# hypothesis shim: property tests must *skip* (not ERROR at collection) when
# hypothesis is not installed.  The stub mirrors the tiny API surface the test
# suite uses (`given`, `settings`, `strategies as st`); any `@given` test body
# is replaced by a pytest.skip.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import types

    class _AnyStrategy:
        """Stands in for any strategy expression: st.foo(...).bar(...) | other."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

        def __or__(self, other):
            return self

    def _given(*_args, **_kwargs):
        def deco(fn):
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed")

            # keep the test's name for reporting, but NOT its signature
            # (pytest must not try to resolve strategy params as fixtures)
            skipper.__name__ = getattr(fn, "__name__", "hypothesis_test")
            skipper.__doc__ = getattr(fn, "__doc__", None)
            return skipper

        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    def _assume(_cond):
        return True

    _stub = types.ModuleType("hypothesis")
    _stub.given = _given
    _stub.settings = _settings
    _stub.assume = _assume
    _stub.HealthCheck = _AnyStrategy()
    _stub.example = _settings
    _stub.strategies = types.ModuleType("hypothesis.strategies")
    _stub.strategies.__getattr__ = lambda name: _AnyStrategy()
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def run_with_devices(code: str, n_devices: int, timeout: int = 300) -> str:
    """Run a python snippet in a subprocess with N fake CPU devices.

    Smoke tests / benches must see 1 device (per spec), so multi-device
    checks re-exec with XLA_FLAGS set before jax init."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"subprocess failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout
