import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def run_with_devices(code: str, n_devices: int, timeout: int = 300) -> str:
    """Run a python snippet in a subprocess with N fake CPU devices.

    Smoke tests / benches must see 1 device (per spec), so multi-device
    checks re-exec with XLA_FLAGS set before jax init."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"subprocess failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout
