import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# ---------------------------------------------------------------------------
# hypothesis shim: property tests must not ERROR at collection when hypothesis
# is not installed.  The stub mirrors the tiny API surface the test suite uses
# (`given`, `settings`, `strategies as st`).  Strategies the stub knows how to
# draw from (integers / sampled_from / booleans / just / tuples / one_of)
# *degrade to seeded-random cases*: the test body runs N times with
# deterministic draws instead of skipping, so property tests keep their teeth
# without the dependency.  Only strategies the stub cannot generate fall back
# to pytest.skip.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import types
    import zlib

    _FALLBACK_EXAMPLES = 8   # seeded-random cases per @given test

    class _AnyStrategy:
        """Stands in for any strategy expression the stub can't draw from:
        st.foo(...).bar(...) | other.  Tests using these skip."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

        def __or__(self, other):
            return self

    class _GenStrategy:
        """A strategy the stub can draw seeded-random examples from."""

        def __init__(self, draw):
            self.draw = draw   # draw(rng) -> value

        def __or__(self, other):
            if isinstance(other, _GenStrategy):
                return _GenStrategy(lambda rng: (self, other)[int(rng.integers(2))].draw(rng))
            return _AnyStrategy()

    def _st_integers(min_value=0, max_value=(1 << 16)):
        return _GenStrategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _st_sampled_from(seq):
        items = list(seq)
        return _GenStrategy(lambda rng: items[int(rng.integers(len(items)))])

    def _st_booleans():
        return _GenStrategy(lambda rng: bool(rng.integers(2)))

    def _st_floats(min_value=0.0, max_value=1.0, **_kw):
        return _GenStrategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    def _st_lists(elements, min_size=0, max_size=10, **_kw):
        if not isinstance(elements, _GenStrategy):
            return _AnyStrategy()
        return _GenStrategy(lambda rng: [
            elements.draw(rng)
            for _ in range(int(rng.integers(min_size, max_size + 1)))])

    def _st_just(value):
        return _GenStrategy(lambda rng: value)

    def _st_tuples(*strats):
        if all(isinstance(s, _GenStrategy) for s in strats):
            return _GenStrategy(lambda rng: tuple(s.draw(rng) for s in strats))
        return _AnyStrategy()

    def _st_one_of(*strats):
        if all(isinstance(s, _GenStrategy) for s in strats):
            return _GenStrategy(
                lambda rng: strats[int(rng.integers(len(strats)))].draw(rng))
        return _AnyStrategy()

    def _given(*arg_strats, **kw_strats):
        all_strats = list(arg_strats) + list(kw_strats.values())
        generable = all(isinstance(s, _GenStrategy) for s in all_strats)

        def deco(fn):
            name = getattr(fn, "__name__", "hypothesis_test")

            if not generable:
                def runner(*a, **k):
                    pytest.skip("hypothesis not installed and stub cannot "
                                "draw from this strategy")
            else:
                def runner(*a, **k):
                    # deterministic per-test seed, stable across runs/workers
                    rng = np.random.default_rng(zlib.crc32(name.encode()))
                    ran = 0
                    for ex in range(_FALLBACK_EXAMPLES):
                        args = tuple(s.draw(rng) for s in arg_strats)
                        kwargs = {kk: s.draw(rng) for kk, s in kw_strats.items()}
                        try:
                            fn(*a, *args, **kwargs, **k)
                            ran += 1
                        except _AssumeFailed:
                            continue
                        except Exception as e:
                            raise AssertionError(
                                f"seeded-random case {ex} failed: "
                                f"args={args} kwargs={kwargs}") from e
                    if not ran:   # don't pass vacuously (hypothesis: Unsatisfied)
                        pytest.skip("all seeded-random cases filtered by assume()")

            # keep the test's name for reporting, but NOT its signature
            # (pytest must not try to resolve strategy params as fixtures)
            runner.__name__ = name
            runner.__doc__ = getattr(fn, "__doc__", None)
            return runner

        return deco

    class _AssumeFailed(Exception):
        pass

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    def _assume(cond):
        if not cond:
            raise _AssumeFailed()
        return True

    _stub = types.ModuleType("hypothesis")
    _stub.given = _given
    _stub.settings = _settings
    _stub.assume = _assume
    _stub.HealthCheck = _AnyStrategy()
    _stub.example = _settings
    _stub.strategies = types.ModuleType("hypothesis.strategies")
    _stub.strategies.integers = _st_integers
    _stub.strategies.sampled_from = _st_sampled_from
    _stub.strategies.booleans = _st_booleans
    _stub.strategies.floats = _st_floats
    _stub.strategies.lists = _st_lists
    _stub.strategies.just = _st_just
    _stub.strategies.tuples = _st_tuples
    _stub.strategies.one_of = _st_one_of
    _stub.strategies.__getattr__ = lambda name: lambda *a, **k: _AnyStrategy()
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def run_with_devices(code: str, n_devices: int, timeout: int = 300) -> str:
    """Run a python snippet in a subprocess with N fake CPU devices.

    Smoke tests / benches must see 1 device (per spec), so multi-device
    checks re-exec with XLA_FLAGS set before jax init."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"subprocess failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout
