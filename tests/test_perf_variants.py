"""Beyond-paper performance variants must preserve semantics."""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.attention import _naive
from repro.models.layers import init_params


def test_bf16_grouped_decode_attention_matches_f32(rng):
    q = jnp.asarray(rng.normal(size=(2, 8, 1, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 2, 64, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 2, 64, 32)), jnp.bfloat16)
    a = _naive(q, k, v, True, jnp.int32(50), 0.0, jnp.int32(49))
    b = _naive(q, k, v, True, jnp.int32(50), 0.0, jnp.int32(49),
               compute_dtype="bf16")
    d = float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
    assert d < 0.03, d


def test_bf16_attention_full_model_close(rng):
    cfg = get_config("llama3.2-1b", smoke=True)
    params = init_params(T.abstract_params(cfg), jax.random.key(0))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)}
    base, _, _, _ = T.forward(params, batch, cfg)
    opt, _, _, _ = T.forward(params, batch, cfg.replace(attn_impl="naive",
                                                     attn_compute_dtype="bf16"))
    scale = float(jnp.max(jnp.abs(base)))
    assert float(jnp.max(jnp.abs(base - opt))) < 0.05 * max(scale, 1.0)


def test_serve_param_dtype_bf16(rng):
    """Serving with bf16 params: logits close to f32-param serving."""
    cfg = get_config("llama3.2-1b", smoke=True)
    params = init_params(T.abstract_params(cfg), jax.random.key(0))
    params_bf = jax.tree.map(lambda a: a.astype(jnp.bfloat16), params)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)}
    a, _, _, _ = T.forward(params, batch, cfg)
    b, _, _, _ = T.forward(params_bf, batch, cfg)
    scale = float(jnp.max(jnp.abs(a)))
    assert float(jnp.max(jnp.abs(a - b.astype(a.dtype)))) < 0.08 * max(scale, 1.0)


def test_report_enrichment_math():
    from repro.configs import SHAPES
    from repro.launch.report import model_bytes
    cfg = get_config("llama3.2-1b")
    tb = model_bytes(cfg, SHAPES["train_4k"])
    assert tb > 24 * cfg.param_count()          # p/m/v read+write floor
    db = model_bytes(cfg, SHAPES["decode_32k"])
    assert db > 2 * cfg.param_count()           # params + cache read
