"""Compiled flit-program engine: sim / run_batch / run_iterative must be
bit-identical to the direct oracle on every topology and placement, stats must
match the seed per-message loop, and NoCStats accounting is golden-pinned so
flit/round bookkeeping can't silently drift."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (NoCConfig, NoCExecutor, cut, make_topology,
                        optimize_placement, PE, place_greedy, place_round_robin,
                        placement_cost, Port, simulate_schedule, TaskGraph)
from tests.conftest import run_with_devices

TOPOLOGIES = ["ring", "mesh", "torus", "fattree"]


def _diamond_graph():
    g = TaskGraph("diamond")
    g.add(PE("src", lambda x: {"a": x + 1, "b": x * 3}, (Port("x", (4,)),),
             (Port("a", (4,)), Port("b", (4,)))))
    g.add(PE("l", lambda a: {"o": a * a}, (Port("a", (4,)),), (Port("o", (4,)),)))
    g.add(PE("r", lambda b: {"o": b - 2}, (Port("b", (4,)),), (Port("o", (4,)),)))
    g.add(PE("join", lambda l, r: {"out": l + r},
             (Port("l", (4,)), Port("r", (4,))), (Port("out", (4,)),)))
    g.connect("src.a", "l.a")
    g.connect("src.b", "r.b")
    g.connect("l.o", "join.l")
    g.connect("r.o", "join.r")
    return g


def _mixed_dtype_graph():
    """Exercise non-float32 contracts through the byte-level framing."""
    g = TaskGraph("mixed")
    g.add(PE("a", lambda x: {"i": (x * 2).astype(jnp.int32),
                             "u": (x + 1).astype(jnp.uint8)},
             (Port("x", (3,)),),
             (Port("i", (3,), np.int32), Port("u", (3,), np.uint8))))
    g.add(PE("b", lambda i: {"y": (i * i).astype(jnp.int32)},
             (Port("i", (3,), np.int32),), (Port("y", (3,), np.int32),)))
    g.add(PE("c", lambda u: {"z": (u + 3).astype(jnp.uint8)},
             (Port("u", (3,), np.uint8),), (Port("z", (3,), np.uint8),)))
    g.connect("a.i", "b.i")
    g.connect("a.u", "c.u")
    return g


def _random_placement(g, n_nodes, seed):
    rng = np.random.default_rng(seed)
    return {name: int(rng.integers(0, n_nodes)) for name in g.pes}


def _check_modes_match(topo_name, seed, n_nodes=6):
    g = _diamond_graph()
    inp = {"src.x": jnp.arange(4.0)}
    topo = make_topology(topo_name, n_nodes)
    placement = _random_placement(g, n_nodes, seed)
    pods = list(np.random.default_rng(seed + 1).integers(0, 2, n_nodes))
    plan = cut(g, placement, pods)
    ex = NoCExecutor(g, topo, placement=placement, plan=plan)
    direct = g.run(inp)
    sim, st_sim = ex.run(inp, mode="sim")
    legacy, st_leg = ex.run(inp, mode="sim_python")
    buffered, st_buf = ex.run(inp, mode="buffered")
    for k in direct:
        assert np.array_equal(np.asarray(sim[k]), np.asarray(direct[k])), (topo_name, k)
        assert np.array_equal(np.asarray(legacy[k]), np.asarray(sim[k])), (topo_name, k)
        assert np.array_equal(np.asarray(buffered[k]), np.asarray(sim[k])), (topo_name, k)
    # the engine's stats must equal the seed per-message loop's, field for field
    assert st_sim.as_dict() == st_leg.as_dict()
    # buffered: static fields identical, transport fields mode-specific
    for f in ("waves", "payload_bytes", "flits", "cross_pod_msgs",
              "cross_pod_wire_bytes", "cross_pod_beats",
              "bridge_beats", "bridge_wire_bytes"):
        assert getattr(st_buf, f) == getattr(st_sim, f), (topo_name, f)
    assert st_buf.switch_cycles == st_buf.rounds > 0
    assert st_sim.switch_cycles == 0
    # batched: B stacked input sets == B direct runs, bit for bit
    B = 3
    binp = {"src.x": np.stack([np.arange(4.0) * (b + 1) for b in range(B)])}
    bouts, st_b = ex.run_batch(binp)
    for b in range(B):
        d = g.run({"src.x": jnp.asarray(binp["src.x"][b])})
        for k in d:
            assert np.array_equal(bouts[k][b], np.asarray(d[k])), (topo_name, b, k)
    assert st_b.rounds == st_sim.rounds and st_b.waves == st_sim.waves
    assert st_b.payload_bytes == B * st_sim.payload_bytes
    assert st_b.flits == B * st_sim.flits


@pytest.mark.parametrize("topo_name", TOPOLOGIES)
@pytest.mark.parametrize("seed", [0, 1])
def test_engine_modes_bit_identical(topo_name, seed):
    _check_modes_match(topo_name, seed)


@given(st.sampled_from(TOPOLOGIES), st.integers(0, 1000))
@settings(max_examples=16, deadline=None)
def test_engine_modes_bit_identical_property(topo_name, seed):
    """sim == run_batch == direct across topologies × random placements."""
    _check_modes_match(topo_name, seed)


@pytest.mark.parametrize("topo_name", TOPOLOGIES)
def test_mixed_dtype_contracts_roundtrip(topo_name):
    g = _mixed_dtype_graph()
    inp = {"a.x": jnp.arange(3.0)}
    ex = NoCExecutor(g, make_topology(topo_name, 4))
    direct = g.run(inp)
    sim, _ = ex.run(inp, mode="sim")
    for k in direct:
        assert np.asarray(sim[k]).dtype == np.asarray(direct[k]).dtype
        assert np.array_equal(np.asarray(sim[k]), np.asarray(direct[k]))


def test_iterative_reuses_compiled_program():
    """run_iterative over the compiled engine == direct-mode iteration."""
    g = _diamond_graph()
    # feedback: join.out -> src.x (shape-compatible loop)
    feedback = [("join.out", "src.x")]
    inp = {"src.x": jnp.arange(4.0)}
    ex = NoCExecutor(g, make_topology("torus", 4))
    out_d, _ = ex.run_iterative(inp, feedback, 4, mode="direct")
    out_s, st = ex.run_iterative(inp, feedback, 4, mode="sim")
    out_l, st_l = ex.run_iterative(inp, feedback, 4, mode="sim_python")
    for k in out_d:
        assert np.array_equal(np.asarray(out_s[k]), np.asarray(out_d[k]))
        assert np.array_equal(np.asarray(out_s[k]), np.asarray(out_l[k]))
    assert st.as_dict() == st_l.as_dict()
    assert st.waves == 4 * 3  # program re-used every iteration


def test_simulate_schedule_batched_oracle(rng):
    for name, n in [("ring", 5), ("mesh", 6), ("torus", 8), ("fattree", 7)]:
        topo = make_topology(name, n)
        msgs = rng.integers(0, 255, (3, n, n, 4)).astype(np.uint8)
        delivered, stats = simulate_schedule(topo, msgs, batched=True)
        assert np.array_equal(delivered, msgs.swapaxes(1, 2)), name
        for b in range(3):
            db, _ = simulate_schedule(topo, msgs[b])
            assert np.array_equal(delivered[b], db), (name, b)


# ---------------------------------------------------------------------------
# golden NoCStats regression — flit/round accounting must not silently drift.
# Stats are value-independent (static contracts), so fixed graphs pin them.
# ---------------------------------------------------------------------------

def test_golden_stats_ldpc_fano():
    from repro.apps import ldpc

    rng = np.random.default_rng(0)
    llr = ldpc.awgn_llr(np.zeros(7, np.int8), 3.0, rng)
    _, _, st = ldpc.decode_on_noc(ldpc.fano_plane_H(), llr, 10)
    assert st.as_dict() == dict(
        waves=20, rounds=60, link_bytes=92160, payload_bytes=840, flits=420,
        cross_pod_msgs=0, cross_pod_wire_bytes=0, cross_pod_beats=0,
        bridge_beats=0, bridge_wire_bytes=0, bridge_stall_rounds=0,
        bridge_peak_fifo=0, switch_cycles=0, switch_stall_cycles=0,
        switch_arb_losses=0, switch_max_queue=0, switch_peak_link_flits=0)


def test_golden_stats_bmvm():
    from repro.apps import bmvm

    rng = np.random.default_rng(0)
    cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)
    A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
    v = rng.integers(0, 2, (64,)).astype(np.uint8)
    lut = bmvm.preprocess(A, cfg)
    out, st = bmvm.iterate_noc_sim(jnp.asarray(lut), v, cfg, 2, topology="mesh")
    assert np.array_equal(out.reshape(1, -1), bmvm.software_ref(A, v[None], 2))
    assert st.as_dict() == dict(
        waves=4, rounds=8, link_bytes=5632, payload_bytes=256, flits=128,
        cross_pod_msgs=0, cross_pod_wire_bytes=0, cross_pod_beats=0,
        bridge_beats=0, bridge_wire_bytes=0, bridge_stall_rounds=0,
        bridge_peak_fifo=0, switch_cycles=0, switch_stall_cycles=0,
        switch_arb_losses=0, switch_max_queue=0, switch_peak_link_flits=0)


def test_golden_stats_ldpc_fano_buffered():
    """Buffered-mode accounting pinned: values stay sim-identical (the decode
    trajectory, waves, payload, flits), while rounds become wormhole cycles
    and the switch counters record the congestion the lock-step schedule
    can't see."""
    from repro.apps import ldpc

    rng = np.random.default_rng(0)
    llr = ldpc.awgn_llr(np.zeros(7, np.int8), 3.0, rng)
    bits, _, st = ldpc.decode_on_noc(ldpc.fano_plane_H(), llr, 10,
                                     mode="buffered")
    assert not bits.any()
    assert st.as_dict() == dict(
        waves=20, rounds=190, link_bytes=2600, payload_bytes=840, flits=420,
        cross_pod_msgs=0, cross_pod_wire_bytes=0, cross_pod_beats=0,
        bridge_beats=0, bridge_wire_bytes=0, bridge_stall_rounds=0,
        bridge_peak_fifo=0, switch_cycles=190, switch_stall_cycles=520,
        switch_arb_losses=40, switch_max_queue=2, switch_peak_link_flits=13)


def test_golden_stats_bmvm_buffered():
    from repro.apps import bmvm

    rng = np.random.default_rng(0)
    cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)
    A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
    v = rng.integers(0, 2, (64,)).astype(np.uint8)
    lut = bmvm.preprocess(A, cfg)
    out, st = bmvm.iterate_noc_sim(jnp.asarray(lut), v, cfg, 2,
                                   topology="mesh", mode="buffered")
    assert np.array_equal(out.reshape(1, -1), bmvm.software_ref(A, v[None], 2))
    assert st.as_dict() == dict(
        waves=4, rounds=90, link_bytes=640, payload_bytes=256, flits=128,
        cross_pod_msgs=0, cross_pod_wire_bytes=0, cross_pod_beats=0,
        bridge_beats=0, bridge_wire_bytes=0, bridge_stall_rounds=0,
        bridge_peak_fifo=0, switch_cycles=90, switch_stall_cycles=304,
        switch_arb_losses=28, switch_max_queue=4, switch_peak_link_flits=6)


def test_nocstats_add_mixed_semantics():
    """NoCStats.add regression (the satellite bugfix): flow counters sum,
    high-water marks (bridge_peak_fifo, switch_max_queue,
    switch_peak_link_flits) merge by max — a sum there would fabricate
    occupancy that never existed."""
    from repro.core import NoCStats

    a = NoCStats(rounds=10, switch_cycles=7, switch_stall_cycles=3,
                 switch_arb_losses=2, switch_max_queue=5,
                 switch_peak_link_flits=4, bridge_peak_fifo=9)
    b = NoCStats(rounds=5, switch_cycles=8, switch_stall_cycles=1,
                 switch_arb_losses=6, switch_max_queue=3,
                 switch_peak_link_flits=11, bridge_peak_fifo=2)
    a.add(b)
    assert a.rounds == 15
    assert a.switch_cycles == 15          # flow: sums
    assert a.switch_stall_cycles == 4
    assert a.switch_arb_losses == 8
    assert a.switch_max_queue == 5        # high-water: max, not 8
    assert a.switch_peak_link_flits == 11  # high-water: max, not 15
    assert a.bridge_peak_fifo == 9


@pytest.mark.slow
def test_golden_stats_spmd_matches_sim_goldens():
    """The spmd lowering must reproduce the exact golden NoCStats above —
    flit/round/link accounting may not drift between transports."""
    run_with_devices("""
import numpy as np, jax.numpy as jnp
from repro.apps import bmvm, ldpc

rng = np.random.default_rng(0)
llr = ldpc.awgn_llr(np.zeros(7, np.int8), 3.0, rng)
_, _, st = ldpc.decode_on_noc(ldpc.fano_plane_H(), llr, 10, mode="spmd")
assert st.as_dict() == dict(
    waves=20, rounds=60, link_bytes=92160, payload_bytes=840, flits=420,
    cross_pod_msgs=0, cross_pod_wire_bytes=0, cross_pod_beats=0,
        bridge_beats=0, bridge_wire_bytes=0, bridge_stall_rounds=0,
        bridge_peak_fifo=0, switch_cycles=0, switch_stall_cycles=0,
        switch_arb_losses=0, switch_max_queue=0,
        switch_peak_link_flits=0), st.as_dict()

rng = np.random.default_rng(0)
cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)
A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
v = rng.integers(0, 2, (64,)).astype(np.uint8)
lut = bmvm.preprocess(A, cfg)
out, st = bmvm.iterate_noc_sim(jnp.asarray(lut), v, cfg, 2, topology="mesh",
                               mode="spmd")
assert np.array_equal(out.reshape(1, -1), bmvm.software_ref(A, v[None], 2))
assert st.as_dict() == dict(
    waves=4, rounds=8, link_bytes=5632, payload_bytes=256, flits=128,
    cross_pod_msgs=0, cross_pod_wire_bytes=0, cross_pod_beats=0,
        bridge_beats=0, bridge_wire_bytes=0, bridge_stall_rounds=0,
        bridge_peak_fifo=0, switch_cycles=0, switch_stall_cycles=0,
        switch_arb_losses=0, switch_max_queue=0,
        switch_peak_link_flits=0), st.as_dict()
print("OK")
""", n_devices=16)


# ---------------------------------------------------------------------------
# placement search
# ---------------------------------------------------------------------------

def test_optimize_placement_beats_baselines():
    from repro.apps import ldpc

    g, _ = ldpc.build_ldpc_graph(ldpc.fano_plane_H())
    topo = make_topology("mesh", 16)
    rr = placement_cost(g, topo, place_round_robin(g, topo))
    gr = placement_cost(g, topo, place_greedy(g, topo))
    opt = optimize_placement(g, topo, iters=1500, seed=0)
    assert set(opt) == set(g.pes)
    assert all(0 <= v < topo.n_nodes for v in opt.values())
    assert placement_cost(g, topo, opt) <= min(rr, gr)
    # one PE per router (14 PEs fit on 16 nodes): the search must not game the
    # hop objective by stacking PEs on one node
    assert len(set(opt.values())) == len(opt)


def test_optimize_placement_cut_aware():
    from repro.apps import ldpc

    g, _ = ldpc.build_ldpc_graph(ldpc.fano_plane_H())
    topo = make_topology("mesh", 16)
    pods = [0] * 8 + [1] * 8
    opt = optimize_placement(g, topo, pod_of_node=pods, iters=1500, seed=0)
    cb_rr = cut(g, place_round_robin(g, topo), pods).cut_bytes(g)
    cb_opt = cut(g, opt, pods).cut_bytes(g)
    assert cb_opt <= cb_rr
    # and the executor still produces oracle-identical results on it
    rng = np.random.default_rng(0)
    llr = ldpc.awgn_llr(np.zeros(7, np.int8), 4.0, rng)
    bits, _, _ = ldpc.decode_on_noc(ldpc.fano_plane_H(), llr, 10,
                                    pods=pods, placement=opt)
    assert not bits.any()


def test_noc_config_serdes_not_shared():
    """default_factory: each NoCConfig gets its own QuasiSerdesConfig."""
    a, b = NoCConfig(), NoCConfig()
    assert a.serdes == b.serdes
    assert a.serdes is not b.serdes
