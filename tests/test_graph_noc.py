"""Phase-1/2 framework: dataflow semantics, NoC executor == direct oracle,
partition cut invariants, wrapper-overhead accounting."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (GraphError, NoCConfig, NoCExecutor, PE, Port, TaskGraph,
                        cut, make_topology, place_greedy, place_round_robin,
                        placement_cost, wrapper_overhead)


def _chain_graph(depth: int, width: int = 4) -> tuple[TaskGraph, dict]:
    g = TaskGraph("chain")
    for i in range(depth):
        def fn(x, _i=i):
            return {"y": x * 2.0 + _i}
        g.add(PE(f"p{i}", fn, (Port("x", (width,)),), (Port("y", (width,)),)))
    for i in range(depth - 1):
        g.connect(f"p{i}.y", f"p{i+1}.x")
    return g, {"p0.x": jnp.arange(float(width))}


def _diamond_graph():
    g = TaskGraph("diamond")
    g.add(PE("src", lambda x: {"a": x + 1, "b": x * 3}, (Port("x", (4,)),),
             (Port("a", (4,)), Port("b", (4,)))))
    g.add(PE("l", lambda a: {"o": a * a}, (Port("a", (4,)),), (Port("o", (4,)),)))
    g.add(PE("r", lambda b: {"o": b - 2}, (Port("b", (4,)),), (Port("o", (4,)),)))
    g.add(PE("join", lambda l, r: {"out": l + r},
             (Port("l", (4,)), Port("r", (4,))), (Port("out", (4,)),)))
    g.connect("src.a", "l.a")
    g.connect("src.b", "r.b")
    g.connect("l.o", "join.l")
    g.connect("r.o", "join.r")
    return g, {"src.x": jnp.arange(4.0)}


def test_firing_order_and_semantics():
    g, inp = _diamond_graph()
    order = g.firing_order()
    assert order.index("src") < order.index("l") < order.index("join")
    out = g.run(inp)
    x = np.arange(4.0)
    assert np.allclose(out["join.out"], (x + 1) ** 2 + (x * 3 - 2))


def test_contract_mismatch_rejected():
    g = TaskGraph("bad")
    g.add(PE("a", lambda x: {"y": x}, (Port("x", (4,)),), (Port("y", (4,)),)))
    g.add(PE("b", lambda x: {"y": x}, (Port("x", (5,)),), (Port("y", (5,)),)))
    with pytest.raises(GraphError):
        g.connect("a.y", "b.x")


def test_cycle_detected():
    g, _ = _chain_graph(2)
    g.connect("p1.y", "p0.x")
    with pytest.raises(GraphError):
        g.firing_order()


@pytest.mark.parametrize("topo_name", ["ring", "mesh", "torus", "fattree"])
@pytest.mark.parametrize("builder", [_chain_graph, _diamond_graph])
def test_noc_executor_matches_direct(topo_name, builder):
    if builder is _chain_graph:
        g, inp = builder(5)
    else:
        g, inp = builder()
    direct = g.run(inp)
    ex = NoCExecutor(g, make_topology(topo_name, 8))
    out, stats = ex.run(inp)
    for k in direct:
        assert np.allclose(out[k], direct[k]), (topo_name, k)
    assert stats.flits > 0 and stats.rounds > 0


@given(st.integers(0, 3))
@settings(max_examples=4, deadline=None)
def test_partition_oblivious(seed):
    """Paper's 'seamless' claim: any pod assignment gives identical results,
    only the stats change."""
    g, inp = _diamond_graph()
    topo = make_topology("mesh", 4)
    placement = place_round_robin(g, topo)
    direct = g.run(inp)
    rng = np.random.default_rng(seed)
    pods = list(rng.integers(0, 2, 4))
    plan = cut(g, placement, pods)
    ex = NoCExecutor(g, topo, placement=placement, plan=plan)
    out, stats = ex.run(inp)
    for k in direct:
        assert np.allclose(out[k], direct[k])
    n_cross_expected = sum(
        1 for c in g.channels
        if pods[placement[c.src_pe]] != pods[placement[c.dst_pe]])
    assert len(plan.cross) == n_cross_expected
    assert len(plan.cross) + len(plan.intra) == len(g.channels)
    if n_cross_expected:
        assert stats.cross_pod_wire_bytes > 0


def test_greedy_placement_not_worse():
    g, _ = _chain_graph(8)
    topo = make_topology("ring", 8)
    rr = placement_cost(g, topo, place_round_robin(g, topo))
    gr = placement_cost(g, topo, place_greedy(g, topo))
    assert gr <= rr


def test_wrapper_overhead_accounting():
    g, _ = _diamond_graph()
    rows = wrapper_overhead(g, NoCConfig(flit_data_width=16, flit_buffer_depth=8))
    assert len(rows) == 4
    for r in rows:
        assert r["with_wrapper_bytes"] > r["wo_wrapper_bytes"] * 0  # framed
        assert r["flit_bytes"] >= r["wo_wrapper_bytes"]            # padding >= payload
        assert r["overhead"] >= 0


def test_wrapper_overhead_non_byte_multiple_flit_width():
    """Regression: FIFO/flit byte accounting must CEIL the per-flit byte
    size.  A 12-bit flit occupies 2 bytes of storage; truncating division
    (12 // 8 == 1) silently under-counted every non-byte-multiple width."""
    g, _ = _diamond_graph()
    cfg = NoCConfig(flit_data_width=12, flit_buffer_depth=8)
    assert cfg.flit_wire_bytes == 2                 # ceil(12 / 8)
    rows = wrapper_overhead(g, cfg)
    for r, r16 in zip(rows, wrapper_overhead(g, NoCConfig(flit_data_width=16,
                                                          flit_buffer_depth=8))):
        # FIFO storage: depth x ports x ceil(width/8) — same as the 16-bit
        # config (both are 2-byte flits), NOT half of it
        assert r["fifo_bytes"] == r16["fifo_bytes"], r["pe"]
        assert r["fifo_bytes"] % cfg.flit_wire_bytes == 0
        # framed size uses the 2-byte wire flit: a 12-bit flit carries one
        # payload byte, so every payload byte occupies exactly 2 on the wire
        assert r["flit_bytes"] == 2 * r["wo_wrapper_bytes"], r["pe"]
    # sub-byte widths must not divide by zero and still frame every byte
    tiny = NoCConfig(flit_data_width=4, flit_buffer_depth=2)
    assert tiny.flit_wire_bytes == 1
    assert tiny.flits_for(5) == 5


def test_flit_framing_single_source():
    """Regression (framing unification): `NoCConfig.flit_framed_bytes` is THE
    ceiling-division framing rule — wrapper_overhead, the compiled wave
    layout and the seed loop all agree with it, for byte-multiple and odd
    flit widths alike."""
    g, inp = _diamond_graph()
    for width in (8, 12, 16, 24):
        cfg = NoCConfig(flit_data_width=width)
        for nbytes in (1, 5, 7, 16, 33):
            assert cfg.flit_framed_bytes(nbytes) == \
                cfg.flits_for(nbytes) * cfg.flit_wire_bytes
            assert cfg.flit_framed_bytes(nbytes) >= nbytes
        rows = wrapper_overhead(g, cfg)
        for r in rows:
            assert r["flit_bytes"] % cfg.flit_wire_bytes == 0
        # the engine's wave layout uses the same rule: per-pair buffer sizes
        # are sums of framed message sizes (16 B float32 messages here)
        ex = NoCExecutor(g, make_topology("mesh", 4), cfg=cfg)
        framed = cfg.flit_framed_bytes(16)
        for prog in ex.programs:
            if prog.slots:
                assert prog.buf_bytes % framed == 0
        # and the engine still matches the seed loop bit for bit + stats
        out_s, st_s = ex.run(inp, mode="sim")
        out_l, st_l = ex.run(inp, mode="sim_python")
        for k in out_s:
            assert np.array_equal(np.asarray(out_s[k]), np.asarray(out_l[k]))
        assert st_s.as_dict() == st_l.as_dict()
