"""SPMD flit-program execution: the compiled schedule→ppermute lowering.

Three layers of guarantees:

* the **compiler** (`compile_routes`) round-trips every message exactly once
  with conserved flit bytes, and its numpy interpreter + analytic stats are
  bit-identical to the handwritten round-by-round simulator (property-tested,
  no devices needed);
* the **device lowering** (`run_route_program` under shard_map) equals the
  transpose oracle on a fake-device mesh;
* the **executor** (`NoCExecutor.run(..., mode="spmd")`) is bit-identical —
  outputs *and* NoCStats — to ``mode="sim"`` and ``mode="direct"`` for all 4
  topologies on all three paper apps (differential harness, subprocess with 8
  fake CPU devices via ``XLA_FLAGS=--xla_force_host_platform_device_count``).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (compile_routes, make_topology, route_program_stats,
                        simulate_route_program, simulate_schedule)
from tests.conftest import run_with_devices

TOPOLOGIES = ["ring", "mesh", "torus", "fattree"]


# ---------------------------------------------------------------------------
# schedule → ppermute compiler (no devices)
# ---------------------------------------------------------------------------

def test_compiled_rounds_match_simulator():
    for name in TOPOLOGIES:
        for n in (2, 4, 6, 8, 9, 12, 16):
            topo = make_topology(name, n)
            prog = compile_routes(topo)
            msgs = np.ones((n, n, 4), np.uint8)
            _, stats = simulate_schedule(topo, msgs)
            assert prog.n_rounds == stats.rounds <= topo.a2a_rounds(), (name, n)


@given(st.sampled_from(TOPOLOGIES), st.sampled_from([2, 4, 6, 8, 9, 12, 16]),
       st.integers(1, 9), st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_compiled_program_matches_simulator(name, n, c, seed):
    """Compiled hop decomposition == handwritten simulator: same delivery,
    same rounds, same link bytes, on random message cubes."""
    rng = np.random.default_rng(seed)
    topo = make_topology(name, n)
    prog = compile_routes(topo)
    msgs = rng.integers(0, 255, size=(n, n, c), dtype=np.uint8)
    d_sim, s_sim = simulate_schedule(topo, msgs)
    d_prog, s_prog = simulate_route_program(prog, msgs)
    assert np.array_equal(d_prog, d_sim)
    assert (s_prog.rounds, s_prog.link_bytes) == (s_sim.rounds, s_sim.link_bytes)
    s_model = route_program_stats(prog, msgs.nbytes)
    assert (s_model.rounds, s_model.link_bytes) == (s_sim.rounds, s_sim.link_bytes)


@given(st.sampled_from(TOPOLOGIES), st.sampled_from([3, 4, 6, 8, 12]),
       st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_hop_decomposition_conserves_messages(name, n, seed):
    """Round-trip property: every (src, dst) pair's flits arrive exactly once
    — nothing dropped, nothing duplicated — and total payload bytes are
    conserved through the per-hop permute rounds."""
    rng = np.random.default_rng(seed)
    topo = make_topology(name, n)
    prog = compile_routes(topo)
    # tag every (src, dst, byte) cell uniquely so duplication/loss is visible
    msgs = rng.permuted(
        np.arange(n * n * 4, dtype=np.uint32)).reshape(n, n, 4)
    delivered, _ = simulate_route_program(prog, msgs)
    # exactly-once delivery to the right node: delivered[d, s] == msgs[s, d]
    for s in range(n):
        for d in range(n):
            assert np.array_equal(delivered[d, s], msgs[s, d]), (name, s, d)
    # conservation: the delivered cube is a permutation of the sent cube
    assert np.array_equal(np.sort(delivered, axis=None), np.sort(msgs, axis=None))
    assert delivered.nbytes == msgs.nbytes


@given(st.sampled_from(TOPOLOGIES), st.sampled_from([4, 8, 9, 16]))
@settings(max_examples=16, deadline=None)
def test_permutation_rounds_are_permutations(name, n):
    """Every compiled hop is a valid ppermute argument: distinct sources,
    distinct destinations, neighbor links only."""
    topo = make_topology(name, n)
    prog = compile_routes(topo)
    for phase in prog.phases:
        size = phase.sched.size
        for rnd in phase.rounds:
            for mv in rnd.moves:
                srcs = [s for s, _ in mv.perm]
                dsts = [d for _, d in mv.perm]
                assert len(set(srcs)) == len(srcs)
                assert len(set(dsts)) == len(dsts)
                for s, d in mv.perm:
                    assert 0 <= s < size and 0 <= d < size
                    assert (d - s) % size in (1, size - 1)   # single hop
                assert len(mv.src_table) == size


# ---------------------------------------------------------------------------
# device lowering (subprocess, fake devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_run_route_program_matches_oracle_on_devices():
    run_with_devices("""
import numpy as np, jax
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core import compile_routes, make_topology
from repro.core.routing import run_route_program
for name in ("ring", "mesh", "torus", "fattree"):
    for n in (4, 12):
        topo = make_topology(name, n)
        prog = compile_routes(topo)
        sizes = [s for _, s in prog.axes]
        names = tuple(a for a, _ in prog.axes)
        mesh = Mesh(np.array(jax.devices()[:n]).reshape(sizes), names)
        def device_fn(local):
            x = local.reshape(local.shape[len(sizes):])
            return run_route_program(x, prog).reshape(local.shape)
        rng = np.random.default_rng(n)
        cube = rng.integers(0, 255, (n, n, 7)).astype(np.uint8)
        sm = shard_map(device_fn, mesh=mesh, in_specs=P(*names),
                       out_specs=P(*names), check_vma=False)
        out = np.asarray(jax.jit(sm)(cube.reshape(sizes + [n, 7])))
        assert np.array_equal(out.reshape(n, n, 7), cube.swapaxes(0, 1)), (name, n)
print("OK")
""", n_devices=12)


# ---------------------------------------------------------------------------
# differential harness: mode="spmd" == mode="sim" == mode="direct"
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_spmd_executor_diamond_all_topologies():
    """Generic-graph differential incl. run_batch: spmd == sim == direct,
    outputs and NoCStats, with random placements and a 2-pod cut."""
    run_with_devices("""
import numpy as np, jax.numpy as jnp
from repro.core import NoCExecutor, PE, Port, TaskGraph, cut, make_topology

def diamond():
    g = TaskGraph("diamond")
    g.add(PE("src", lambda x: {"a": x + 1, "b": x * 3}, (Port("x", (4,)),),
             (Port("a", (4,)), Port("b", (4,)))))
    g.add(PE("l", lambda a: {"o": a * a}, (Port("a", (4,)),), (Port("o", (4,)),)))
    g.add(PE("r", lambda b: {"o": b - 2}, (Port("b", (4,)),), (Port("o", (4,)),)))
    g.add(PE("join", lambda l, r: {"out": l + r},
             (Port("l", (4,)), Port("r", (4,))), (Port("out", (4,)),)))
    g.connect("src.a", "l.a"); g.connect("src.b", "r.b")
    g.connect("l.o", "join.l"); g.connect("r.o", "join.r")
    return g

for topo_name in ("ring", "mesh", "torus", "fattree"):
    for seed in (0, 1, 2):
        g = diamond()
        n = 6
        rng = np.random.default_rng(seed)
        placement = {name: int(rng.integers(0, n)) for name in g.pes}
        pods = list(np.random.default_rng(seed + 1).integers(0, 2, n))
        ex = NoCExecutor(g, make_topology(topo_name, n), placement=placement,
                         plan=cut(g, placement, pods))
        inp = {"src.x": jnp.arange(4.0)}
        direct = g.run(inp)
        sim, st_sim = ex.run(inp, mode="sim")
        spmd, st_spmd = ex.run(inp, mode="spmd")
        buffered, st_buf = ex.run(inp, mode="buffered")
        for k in direct:
            assert np.array_equal(np.asarray(spmd[k]), np.asarray(direct[k])), (topo_name, k)
            assert np.array_equal(np.asarray(spmd[k]), np.asarray(sim[k])), (topo_name, k)
            assert np.array_equal(np.asarray(buffered[k]), np.asarray(sim[k])), (topo_name, k)
        assert st_spmd.as_dict() == st_sim.as_dict(), (topo_name, seed)
        # buffered payload parity: static accounting matches sim exactly
        for f in ("waves", "payload_bytes", "flits", "cross_pod_msgs",
                  "cross_pod_wire_bytes", "cross_pod_beats"):
            assert getattr(st_buf, f) == getattr(st_sim, f), (topo_name, seed, f)
        assert st_buf.switch_cycles == st_buf.rounds > 0, (topo_name, seed)
        B = 3
        binp = {"src.x": np.stack([np.arange(4.0) * (b + 1) for b in range(B)])}
        bs, stb_sim = ex.run_batch(binp, mode="sim")
        bp, stb_spmd = ex.run_batch(binp, mode="spmd")
        bd, _ = ex.run_batch(binp, mode="direct")
        for k in bs:
            assert np.array_equal(bp[k], bs[k]), (topo_name, k)
            assert np.array_equal(bp[k], bd[k]), (topo_name, k)
        assert stb_spmd.as_dict() == stb_sim.as_dict(), (topo_name, seed)
print("OK")
""", n_devices=8)


@pytest.mark.slow
def test_spmd_differential_bmvm():
    """BMVM (case study III) on all 4 topologies: spmd == sim == software."""
    run_with_devices("""
import numpy as np, jax.numpy as jnp
from repro.apps import bmvm

rng = np.random.default_rng(0)
cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)          # 4 PEs -> 8 NoC nodes
A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
v = rng.integers(0, 2, (64,)).astype(np.uint8)
lut = bmvm.preprocess(A, cfg)
sw = bmvm.software_ref(A, v[None], 3)
for topo in ("ring", "mesh", "torus", "fattree"):
    out_sim, st_sim = bmvm.iterate_noc_sim(jnp.asarray(lut), v, cfg, 3,
                                           topology=topo, mode="sim")
    out_spmd, st_spmd = bmvm.iterate_noc_sim(jnp.asarray(lut), v, cfg, 3,
                                             topology=topo, mode="spmd")
    assert np.array_equal(out_spmd, out_sim), topo
    assert np.array_equal(out_spmd.reshape(1, -1), sw), topo
    assert st_spmd.as_dict() == st_sim.as_dict(), topo
print("OK")
""", n_devices=8)


@pytest.mark.slow
def test_spmd_differential_ldpc():
    """LDPC min-sum (case study I) on all 4 topologies: identical decode and
    flit accounting between spmd and sim."""
    run_with_devices("""
import numpy as np
from repro.apps import ldpc

rng = np.random.default_rng(0)
H = ldpc.fano_plane_H()
llr = ldpc.awgn_llr(np.zeros(7, np.int8), 3.0, rng)
for topo in ("ring", "mesh", "torus", "fattree"):
    bits_sim, post_sim, st_sim = ldpc.decode_on_noc(H, llr, 5, topology=topo,
                                                    n_nodes=8, mode="sim")
    bits_spmd, post_spmd, st_spmd = ldpc.decode_on_noc(H, llr, 5, topology=topo,
                                                       n_nodes=8, mode="spmd")
    assert np.array_equal(bits_spmd, bits_sim), topo
    assert np.array_equal(post_spmd, post_sim), topo
    assert st_spmd.as_dict() == st_sim.as_dict(), topo
print("OK")
""", n_devices=8)


@pytest.mark.slow
def test_spmd_differential_particle_filter():
    """Particle filter (case study II) on all 4 topologies: identical track."""
    run_with_devices("""
import numpy as np
from repro.apps import particle_filter as pf

rng = np.random.default_rng(3)
cfg = pf.PFConfig(img=64, roi=16, n_particles=64, n_bins=16)
frames, _ = pf.synth_video(cfg, 4, rng)
for topo in ("ring", "mesh", "torus", "fattree"):
    c_sim, st_sim = pf.track_on_noc(frames, cfg, n_pe=4, topology=topo,
                                    n_nodes=8, mode="sim")
    c_spmd, st_spmd = pf.track_on_noc(frames, cfg, n_pe=4, topology=topo,
                                      n_nodes=8, mode="spmd")
    assert np.array_equal(c_spmd, c_sim), topo
    assert st_spmd.as_dict() == st_sim.as_dict(), topo
print("OK")
""", n_devices=8)


# ---------------------------------------------------------------------------
# placement → device-mesh assignment
# ---------------------------------------------------------------------------

def test_placement_to_device_coords():
    from repro.core import (node_device_coords, optimize_placement,
                            placement_to_device_coords)
    from repro.apps import ldpc

    g, _ = ldpc.build_ldpc_graph(ldpc.fano_plane_H())
    topo = make_topology("mesh", 16)
    placement = optimize_placement(g, topo, iters=300, seed=0)
    coords = placement_to_device_coords(placement, topo)
    assert set(coords) == set(g.pes)
    for pe, node in placement.items():
        x, y = topo.coords(node)
        assert coords[pe] == {"noc_y": y, "noc_x": x}
        # round-trip: coords identify the node the PE was placed on
        assert topo.node(coords[pe]["noc_x"], coords[pe]["noc_y"]) == node
    ring = make_topology("ring", 5)
    assert node_device_coords(ring, 3) == {"noc": 3}
    with pytest.raises(ValueError):
        node_device_coords(ring, 7)


def test_mesh_for_topology_insufficient_devices():
    """Single-device default environment: the spmd path must fail fast with
    an actionable error, not a shape error deep in shard_map."""
    import jax

    from repro.core import mesh_for_topology

    topo = make_topology("ring", 64)
    if jax.device_count() >= 64:
        pytest.skip("environment has enough devices")
    with pytest.raises(RuntimeError, match="xla_force_host_platform_device_count"):
        mesh_for_topology(topo)
