"""Multi-device integration (subprocess, fake CPU devices): MoE engines,
cross-pod serdes training, elastic rescale, roofline HLO parsing."""
import pytest

from tests.conftest import run_with_devices


@pytest.mark.slow
def test_moe_engines_agree_across_mesh():
    """gather + noc engines (ALL 4 topologies) == dense oracle on a
    (data=2, model=4) mesh, with drop-free dispatch stats."""
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.launch.mesh import set_mesh
from repro.models import moe as M
from repro.models.layers import init_params
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
rng = np.random.default_rng(0)
dense = M.MoEConfig(d_model=32, n_experts=8, top_k=2, d_ff=64,
                    capacity_factor=8.0, impl="dense")
params = init_params(M.moe_specs(dense), jax.random.key(0))
x = jnp.asarray(rng.normal(size=(4, 16, 32)), jnp.float32)
engines = [M.MoEConfig(32, 8, 2, 64, capacity_factor=8.0, impl="gather")]
engines += [M.MoEConfig(32, 8, 2, 64, capacity_factor=8.0, impl="noc",
                        noc_topology=t)
            for t in ("fattree", "ring", "mesh2d", "torus2d")]
with set_mesh(mesh):
    ref, aux_ref, _ = M.moe_apply(params, x, dense)
    for c in engines:
        out, aux, st = M.moe_apply(params, x, c)
        tag = (c.impl, c.noc_topology)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-4, (tag, err)
        # capacity 8x => no drops => exact combine; aux equal too
        assert abs(float(aux) - float(aux_ref)) < 1e-4, tag
        assert int(st.drops) == 0 and st.fallback is None, tag
        if c.impl == "noc":
            assert st.engine == "noc" and st.topology == c.noc_topology
            assert st.rounds > 0 and st.flits > 0 and st.link_bytes > 0
print("OK")
""", n_devices=8)


@pytest.mark.slow
def test_moe_noc_ring_schedule():
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.launch.mesh import set_mesh
from repro.models import moe as M
from repro.models.layers import init_params
mesh = Mesh(np.array(jax.devices()).reshape(1, 4), ("data", "model"))
rng = np.random.default_rng(1)
dense = M.MoEConfig(32, 8, 2, 64, capacity_factor=8.0, impl="dense")
ring = M.MoEConfig(32, 8, 2, 64, capacity_factor=8.0, impl="noc", noc_topology="ring")
params = init_params(M.moe_specs(dense), jax.random.key(0))
x = jnp.asarray(rng.normal(size=(2, 8, 32)), jnp.float32)
with set_mesh(mesh):
    ref, _, _ = M.moe_apply(params, x, dense)
    out, _, st = M.moe_apply(params, x, ring)
assert float(jnp.max(jnp.abs(out - ref))) < 1e-4
assert st.rounds == 2 * 3   # ring(4) unidir: 3 rounds out + 3 back
print("OK")
""", n_devices=4)


@pytest.mark.slow
def test_train_serdes_pod_sync_matches_auto():
    """2-pod mesh: quasi-SERDES cross-pod gradient sync (lossless + bf16) vs
    XLA flat all-reduce."""
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.configs import get_config
from repro.core.serdes import QuasiSerdesConfig
from repro.launch.mesh import set_mesh
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.models.layers import init_params
from repro.optim import AdamWConfig, adamw_init
mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2), ("pod", "data", "model"))
cfg = get_config("llama3.2-1b", smoke=True)
params = init_params(T.abstract_params(cfg), jax.random.key(0))
state = {"params": params, "opt": adamw_init(params)}
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
opt = AdamWConfig(lr=1e-3)
outs = {}
with set_mesh(mesh):
    for name, kw in [("auto", dict(pod_sync="auto")),
                     ("serdes_none", dict(pod_sync="serdes",
                                          serdes=QuasiSerdesConfig(compress="none"))),
                     ("serdes_bf16", dict(pod_sync="serdes",
                                          serdes=QuasiSerdesConfig(compress="bf16")))]:
        step = make_train_step(cfg, mesh, opt, **kw)
        st2, mets = jax.jit(step)(state, batch)
        outs[name] = (float(mets["loss"]), st2["params"])
l0 = outs["auto"][0]
for name in ("serdes_none", "serdes_bf16"):
    assert abs(outs[name][0] - l0) < 1e-3, (name, outs[name][0], l0)
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(outs[name][1]),
                            jax.tree.leaves(outs["auto"][1])))
    tol = 1e-5 if name == "serdes_none" else 5e-3
    assert d < tol, (name, d)
print("OK")
""", n_devices=8)


@pytest.mark.slow
def test_elastic_rescale_resumes():
    """Train 4 steps on 8 devices, checkpoint, restore + reshard on 4 devices,
    continue — loss stays finite and state resharding is exact."""
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        run_with_devices(f"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.configs import get_config
from repro.launch.mesh import set_mesh
from repro.launch.steps import make_train_step, shardings_for_params
from repro.models import transformer as T
from repro.models.layers import init_params
from repro.optim import AdamWConfig, adamw_init
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
cfg = get_config("llama3.2-1b", smoke=True)
params = init_params(T.abstract_params(cfg), jax.random.key(0))
state = {{"params": params, "opt": adamw_init(params)}}
rng = np.random.default_rng(0)
batch = {{"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}}
with set_mesh(mesh):
    step = jax.jit(make_train_step(cfg, mesh, AdamWConfig(lr=1e-3)))
    for _ in range(4):
        state, mets = step(state, batch)
cm = CheckpointManager(CheckpointConfig({d!r}, async_save=False))
cm.save(4, state)
print("saved", float(mets["loss"]))
""", n_devices=8)
        run_with_devices(f"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.configs import get_config
from repro.launch.mesh import set_mesh
from repro.launch.steps import make_train_step, shardings_for_params
from repro.models import transformer as T
from repro.models.layers import init_params
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import factor_mesh
shape, axes = factor_mesh(4, prefer_model=2)
mesh = Mesh(np.array(jax.devices()).reshape(shape), axes)
cfg = get_config("llama3.2-1b", smoke=True)
proto = {{"params": init_params(T.abstract_params(cfg), jax.random.key(0))}}
proto["opt"] = __import__("repro.optim", fromlist=["adamw_init"]).adamw_init(proto["params"])
cm = CheckpointManager(CheckpointConfig({d!r}, async_save=False))
psh = shardings_for_params(cfg, mesh)
sh = {{"params": psh, "opt": {{"m": psh, "v": psh, "step": None}}}}
state, step_no, _ = cm.restore(proto, shardings=sh)
assert step_no == 4
rng = np.random.default_rng(0)
batch = {{"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}}
with set_mesh(mesh):
    step = jax.jit(make_train_step(cfg, mesh, AdamWConfig(lr=1e-3)))
    state, mets = step(state, batch)
assert np.isfinite(float(mets["loss"]))
print("resumed on 4 devices, loss", float(mets["loss"]))
""", n_devices=4)


def test_roofline_hlo_parsing():
    from repro.launch.roofline import _shape_bytes, collective_bytes
    assert _shape_bytes("bf16[128,4096]") == 128 * 4096 * 2
    assert _shape_bytes("(f32[8], u8[16])") == 48
    hlo = '''
  %ar = bf16[1024] all-reduce(%x), replica_groups={}
  %ag.1 = f32[2048] all-gather(%y), dimensions={0}
  %cp = u8[100] collective-permute(%z)
  %add = f32[4] add(%a, %b)
'''
    cb = collective_bytes(hlo)
    assert cb["all-reduce"] == 2048
    assert cb["all-gather"] == 8192
    assert cb["collective-permute"] == 100
    assert cb["n_ops"] == 3


def test_dryrun_cell_api_smoke():
    """cell_supported + input_specs wiring (the full dry-run runs offline)."""
    from repro.configs import SHAPES, get_config, input_specs
    cfg = get_config("llama3.2-1b")
    sp = input_specs(cfg, SHAPES["train_4k"])
    assert sp["tokens"].shape == (256, 4096)
    sp = input_specs(cfg, SHAPES["decode_32k"])
    assert sp["tokens"].shape == (128, 1)
    w = get_config("whisper-large-v3")
    sp = input_specs(w, SHAPES["prefill_32k"])
    assert sp["frames"].shape == (32, 1500, 128)
