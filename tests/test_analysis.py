"""Static verifier property suite (`repro.analysis`).

The verifier's word is held against the simulators:

* **deadlock proofs** — `deadlock_cycle` verdicts pinned on known
  (topology, n_vcs) combinations, including the two the old hand guard got
  wrong (2-node ring and 2x2 torus are provably safe at 1 VC); the property
  gate: verifier-safe ⇒ `simulate_switch` drains an all-to-all at depth 1,
  verifier-cyclic ⇒ construction is rejected with the concrete cycle;
* **delivery proofs** — compiled route programs / bridged programs / wave
  layouts verify clean, and seeded corruptions (wrong src_table, dropped
  bridge, duplicated pack index, transposed gather) are each caught with the
  right NOC0xx code;
* **capacity bounds** — exact fields (flits, payload/link bytes, bridge
  counters) equal the buffered/bridged NoCStats bit-for-bit on all four
  topologies, peaks bound the measured high-water marks, and a competing-flow
  construction shows the queue bound *tight* (bound == measured == depth);
* **linter + wiring** — NOC0xx codes from the config linters,
  ``NoCExecutor(verify=)`` strict/warn/off behavior, eager NoCConfig
  validation, the runtime DeadlockError culprit-cycle report, and the
  `python -m repro.analysis.lint` CLI;
* **traffic edge cases** — zero/singular fabrics, hotspot_frac 0/1, row-sum
  conservation of every pattern's matrix.

Property tests use the hypothesis shim in tests/conftest.py (seeded random
cases when hypothesis is absent).
"""
import dataclasses
import warnings

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro import analysis as A
from repro.core import (NoCConfig, NoCExecutor, PE, Port, TaskGraph, cut,
                        make_topology)
from repro.core.routing import compile_routes
from repro.core.switch import (DeadlockError, Packet, SwitchConfig,
                               simulate_switch)
from repro.core.traffic import (PATTERNS, TrafficConfig, generate_traffic,
                                traffic_matrix)

TOPOLOGIES = ["ring", "mesh", "torus", "fattree"]


def _diamond():
    g = TaskGraph("diamond")
    g.add(PE("src", lambda x: {"a": x + 1, "b": x * 3}, (Port("x", (4,)),),
             (Port("a", (4,)), Port("b", (4,)))))
    g.add(PE("l", lambda a: {"o": a * a}, (Port("a", (4,)),), (Port("o", (4,)),)))
    g.add(PE("r", lambda b: {"o": b - 2}, (Port("b", (4,)),), (Port("o", (4,)),)))
    g.add(PE("join", lambda l, r: {"out": l + r},
             (Port("l", (4,)), Port("r", (4,))), (Port("out", (4,)),)))
    g.connect("src.a", "l.a")
    g.connect("src.b", "r.b")
    g.connect("l.o", "join.l")
    g.connect("r.o", "join.r")
    return g


def _ldpc_setup():
    from repro.apps import ldpc

    H = ldpc.fano_plane_H()
    g, _ = ldpc.build_ldpc_graph(H)
    rng = np.random.default_rng(0)
    llr = ldpc.awgn_llr(np.zeros(7, np.int8), 3.0, rng)
    inputs = {}
    for b in range(H.shape[1]):
        inputs[f"bit{b}.u0"] = jnp.asarray(llr[b:b + 1], jnp.float32)
    for c in range(H.shape[0]):
        for j_c, b in enumerate(np.nonzero(H[c])[0]):
            inputs[f"chk{c}.u{j_c}"] = jnp.asarray(llr[b:b + 1], jnp.float32)
    return g, inputs


# ---------------------------------------------------------------------------
# channel-dependency deadlock proofs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tname,n,vcs,safe", [
    ("ring", 8, 1, False),     # the classic cyclic wedge
    ("ring", 8, 2, True),      # dateline escape VC breaks it
    ("ring", 2, 1, True),      # single-hop routes: the hand guard's false positive
    ("torus", 4, 1, True),     # 2x2 torus: ditto
    ("torus", 16, 1, False),
    ("torus", 16, 2, True),
    ("mesh", 16, 1, True),     # no wraparound: safe at any VC count
    ("fattree", 8, 1, True),
])
def test_deadlock_verdicts_pinned(tname, n, vcs, safe):
    topo = make_topology(tname, n)
    cyc = A.deadlock_cycle(topo, vcs)
    assert (cyc is None) == safe, (tname, n, vcs, cyc)
    diags = A.check_deadlock_freedom(topo, vcs)
    if safe:
        assert diags == []
    else:
        assert [d.code for d in diags] == ["NOC001"]
        # the report names a concrete channel cycle, and it is a real cycle:
        # consecutive channels chain head-to-tail through the same router
        assert "->" in diags[0].message and "n_vcs" in diags[0].message
        for (u, v, _), (u2, _, _) in zip(cyc, cyc[1:] + cyc[:1]):
            assert v == u2, cyc


def test_check_deadlock_freedom_rejects_zero_vcs():
    diags = A.check_deadlock_freedom(make_topology("mesh", 4), 0)
    assert [d.code for d in diags] == ["NOC002"]


@settings(max_examples=24, deadline=None)
@given(st.sampled_from(TOPOLOGIES), st.integers(min_value=2, max_value=9),
       st.integers(min_value=1, max_value=3))
def test_verifier_verdict_matches_simulator(tname, n, vcs):
    """verifier-safe ⇒ an adversarial depth-1 all-to-all drains;
    verifier-cyclic ⇒ simulate_switch refuses the combo up front."""
    topo = make_topology(tname, n)
    pkts = [Packet(s, d, 2) for s in range(n) for d in range(n) if s != d]
    scfg = SwitchConfig(buffer_depth=1, n_vcs=vcs, max_cycles=100_000)
    if A.deadlock_cycle(topo, vcs) is None:
        res = simulate_switch(topo, pkts, scfg, verify=False)
        assert res.stats.packets == len(pkts), (tname, n, vcs)
    else:
        with pytest.raises(ValueError, match="NOC001"):
            simulate_switch(topo, pkts, scfg)


def test_one_vc_combos_the_hand_guard_rejected_now_run():
    """ring n=2 and 2x2 torus are provably safe at 1 VC and must simulate."""
    for tname, n in (("ring", 2), ("torus", 4)):
        topo = make_topology(tname, n)
        pkts = [Packet(s, d, 3) for s in range(n) for d in range(n) if s != d]
        res = simulate_switch(topo, pkts,
                              SwitchConfig(buffer_depth=1, n_vcs=1))
        assert res.stats.packets == len(pkts)


def test_runtime_deadlock_reports_culprit_cycle():
    topo = make_topology("ring", 8)
    pkts = [Packet(s, (s + 4) % 8, 4) for s in range(8) for _ in range(4)]
    with pytest.raises(DeadlockError, match="culprit wait cycle"):
        simulate_switch(topo, pkts,
                        SwitchConfig(buffer_depth=1, n_vcs=1,
                                     max_cycles=50_000), verify=False)


def test_find_wait_cycle():
    assert A.find_wait_cycle({1: 2, 2: 3, 3: 1, 9: 1}) in (
        [1, 2, 3], [2, 3, 1], [3, 1, 2])
    assert A.find_wait_cycle({1: 2, 2: 3}) is None
    assert A.find_wait_cycle({}) is None


# ---------------------------------------------------------------------------
# delivery / conservation proofs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tname,n", [("ring", 8), ("mesh", 16),
                                     ("torus", 16), ("fattree", 8),
                                     ("ring", 5), ("mesh", 6)])
def test_route_programs_verify_clean(tname, n):
    assert A.verify_route_program(compile_routes(make_topology(tname, n))) == []


def _corrupt_first_move(prog, **repl):
    ph = prog.phases[0]
    rnd = ph.rounds[0]
    mv = dataclasses.replace(rnd.moves[0], **repl)
    rnd = dataclasses.replace(rnd, moves=(mv,) + rnd.moves[1:])
    ph = dataclasses.replace(ph, rounds=(rnd,) + ph.rounds[1:])
    return dataclasses.replace(prog, phases=(ph,) + prog.phases[1:])


def test_corrupted_route_program_is_caught():
    prog = compile_routes(make_topology("ring", 8))
    mv = prog.phases[0].rounds[0].moves[0]
    # erase a commit: some (dst, src) pair is never delivered
    bad = _corrupt_first_move(prog, src_table=tuple(-1 for _ in mv.src_table))
    assert "NOC003" in {d.code for d in A.verify_route_program(bad)}
    # mis-route a hop to a non-neighbor
    (s0, d0), *rest = mv.perm
    bad = _corrupt_first_move(prog, perm=((s0, (d0 + 1) % 8),) + tuple(rest))
    assert "NOC003" in {d.code for d in A.verify_route_program(bad)}
    # double-deliver: point a commit at a pair the diagonal already covered
    bad = _corrupt_first_move(prog, src_table=tuple(
        i for i, _ in enumerate(mv.src_table)))
    assert "NOC003" in {d.code for d in A.verify_route_program(bad)}


@pytest.mark.parametrize("tname", TOPOLOGIES)
def test_wave_layouts_verify_clean(tname):
    g = _diamond()
    topo = make_topology(tname, 6)
    ex = NoCExecutor(g, topo)
    n = topo.n_nodes
    for w, prog in enumerate(ex.programs):
        assert A.verify_wave_layout(prog, n, f"w{w}",
                                    ex.cfg.flit_wire_bytes) == []


def test_corrupted_wave_layout_is_caught():
    ex = NoCExecutor(_diamond(), make_topology("mesh", 6))
    prog = next(p for p in ex.programs if p.pack_idx.size > 1)
    n = 6
    # duplicate pack index: two payload bytes scatter onto one cube byte
    pack = prog.pack_idx.copy()
    pack[1] = pack[0]
    bad = dataclasses.replace(prog, pack_idx=pack)
    assert "NOC003" in {d.code for d in A.verify_wave_layout(bad, n, "w")}
    # gather not the transpose image of pack
    gather = prog.gather_idx.copy()
    gather[0], gather[-1] = gather[-1], gather[0]
    bad = dataclasses.replace(prog, gather_idx=gather)
    assert "NOC003" in {d.code for d in A.verify_wave_layout(bad, n, "w")}


def test_bridged_program_verifies_clean_and_corruptions_caught():
    g = _diamond()
    topo = make_topology("mesh", 6)
    placement = {"src": 0, "l": 2, "r": 3, "join": 5}
    pods = [0, 0, 0, 1, 1, 1]
    plan = cut(g, placement, pods)
    ex = NoCExecutor(g, topo, placement=placement, plan=plan)
    bprog = ex._ensure_bridge()
    assert A.errors(A.verify_bridged_program(bprog)) == []
    # wrong pod table length
    bad = dataclasses.replace(bprog, pod_of_node=(0, 0, 1))
    assert "NOC008" in {d.code for d in A.verify_bridged_program(bad)}
    # drop a bridge: some cut hop loses its serdes endpoint
    assert bprog.bridges, "cut produced no bridges; test setup is broken"
    bad = dataclasses.replace(bprog, bridges=bprog.bridges[:-1])
    assert "NOC004" in {d.code for d in A.verify_bridged_program(bad)}
    # relabel a node's pod: bridge pod tags now disagree
    flipped = list(bprog.pod_of_node)
    flipped[0] = 1 - flipped[0]
    bad = dataclasses.replace(bprog, pod_of_node=tuple(flipped))
    assert "NOC004" in {d.code for d in A.verify_bridged_program(bad)}


# ---------------------------------------------------------------------------
# capacity bounds vs measured NoCStats
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tname,n", [("ring", 8), ("mesh", 16),
                                     ("torus", 16), ("fattree", 8)])
def test_bounds_exact_and_sound_vs_buffered_ldpc(tname, n):
    g, inputs = _ldpc_setup()
    ex = NoCExecutor(g, make_topology(tname, n))
    rep = A.executor_bounds(ex)
    _, st = ex.run(inputs, mode="buffered")
    # exact fields: bit-for-bit against the cycle-accurate simulation
    assert rep.flits == st.flits
    assert rep.payload_bytes == st.payload_bytes
    assert rep.link_bytes == st.link_bytes
    # sound bounds on the high-water marks
    assert st.switch_max_queue <= rep.peak_queue
    assert st.switch_peak_link_flits <= rep.peak_link_flits


@pytest.mark.parametrize("tname", TOPOLOGIES)
def test_bounds_exact_and_sound_vs_buffered_diamond(tname):
    g = _diamond()
    ex = NoCExecutor(g, make_topology(tname, 6))
    rep = A.executor_bounds(ex)
    _, st = ex.run({"src.x": jnp.arange(4.0)}, mode="buffered")
    assert (rep.flits, rep.payload_bytes, rep.link_bytes) == \
        (st.flits, st.payload_bytes, st.link_bytes)
    assert st.switch_max_queue <= rep.peak_queue
    assert st.switch_peak_link_flits <= rep.peak_link_flits


def test_queue_bound_tight_under_competing_flows():
    """Three sources streaming into one ejection port: the losing input FIFOs
    fill to depth, so bound == measured == switch_buffer_depth, and NOC005
    predicts exactly that."""
    g = TaskGraph("star")
    for i in (1, 2, 3):
        g.add(PE(f"s{i}", lambda x: {"o": x * 2.0}, (Port("x", (32,)),),
                 (Port("o", (32,)),)))
    g.add(PE("sink", lambda a, b, c: {"y": a + b + c},
             (Port("a", (32,)), Port("b", (32,)), Port("c", (32,))),
             (Port("y", (32,)),)))
    g.connect("s1.o", "sink.a")
    g.connect("s2.o", "sink.b")
    g.connect("s3.o", "sink.c")
    ex = NoCExecutor(g, make_topology("ring", 4),
                     placement={"s1": 1, "s2": 2, "s3": 3, "sink": 0},
                     verify="off")
    rep = A.executor_bounds(ex)
    _, st = ex.run({f"s{i}.x": jnp.arange(32.0) + i for i in (1, 2, 3)},
                   mode="buffered")
    depth = ex.cfg.switch_buffer_depth
    assert rep.peak_queue == st.switch_max_queue == depth
    assert any(d.code == "NOC005" for d in rep.diagnostics)


def test_bridge_counters_exact_vs_bridged_sim():
    g, inputs = _ldpc_setup()
    from repro.core import place_round_robin

    topo = make_topology("mesh", 16)
    placement = place_round_robin(g, topo)
    pods = [0] * 8 + [1] * 8
    plan = cut(g, placement, pods)
    ex = NoCExecutor(g, topo, placement=placement, plan=plan)
    rep = A.executor_bounds(ex)
    _, st = ex.run(inputs, mode="sim")
    assert rep.bridge_beats == st.bridge_beats
    assert rep.bridge_wire_bytes == st.bridge_wire_bytes
    assert rep.bridge_stall_rounds == st.bridge_stall_rounds
    assert rep.bridge_peak_fifo == st.bridge_peak_fifo


def test_check_traffic_codes():
    topo = make_topology("mesh", 16)
    # under saturation: clean
    assert A.check_traffic(topo, TrafficConfig(injection_rate=0.01)) == []
    # hopeless offered load
    diags = A.check_traffic(topo, TrafficConfig(injection_rate=50.0))
    assert [d.code for d in diags] == ["NOC006"]
    # single-node fabric: nothing can be sent
    diags = A.check_traffic(make_topology("ring", 1),
                            TrafficConfig(injection_rate=0.1))
    assert [d.code for d in diags] == ["NOC014"]
    # hotspot node outside the fabric
    diags = A.check_traffic(topo, TrafficConfig(pattern="hotspot",
                                                injection_rate=0.1,
                                                hotspot=99))
    assert [d.code for d in diags] == ["NOC014"]


# ---------------------------------------------------------------------------
# linters + executor wiring
# ---------------------------------------------------------------------------

def test_lint_placement_codes():
    g = _diamond()
    topo = make_topology("mesh", 4)
    ok = {"src": 0, "l": 1, "r": 2, "join": 3}
    assert A.lint_placement(g, topo, ok) == []
    codes = {d.code for d in A.lint_placement(
        g, topo, {**ok, "ghost": 1, "join": 9})}
    assert codes == {"NOC007"}
    # missing PE
    missing = dict(ok)
    del missing["join"]
    assert {d.code for d in A.lint_placement(g, topo, missing)} == {"NOC007"}


def test_lint_noc_config_codes():
    topo = make_topology("ring", 8)
    assert A.lint_noc_config(NoCConfig(), topo) == []
    # framing warning: 12-bit flits pad to 2 bytes
    diags = A.lint_noc_config(NoCConfig(flit_data_width=12))
    assert "NOC010" in {d.code for d in diags}
    # cyclic combo flagged through the config linter too
    diags = A.lint_noc_config(NoCConfig(switch_vcs=1), topo)
    assert "NOC001" in {d.code for d in diags}


def test_lint_model_config_codes():
    from repro import configs

    moe = configs.get_config("qwen3-moe-235b-a22b")
    assert any("moe" in layer for layer in moe.pattern)
    assert A.lint_model_config(moe, n_ranks=None) == []
    assert moe.n_experts % 4 == 0
    assert A.lint_model_config(moe, n_ranks=4) == []
    diags = A.lint_model_config(moe, n_ranks=7)
    assert [d.code for d in diags] == ["NOC011"]
    assert "dense reference" in diags[0].message
    dense = configs.get_config("llama3.2-1b")
    assert A.lint_model_config(dense, n_ranks=7) == []


def test_executor_verify_modes():
    g = _diamond()
    bad_cfg = NoCConfig(switch_vcs=1)
    ring = make_topology("ring", 8)
    # strict (default): VerificationError carrying the diagnostics
    with pytest.raises(A.VerificationError) as ei:
        NoCExecutor(g, ring, cfg=bad_cfg)
    assert "NOC001" in {d.code for d in ei.value.diagnostics}
    # warn: constructs, but reports
    with pytest.warns(UserWarning, match="NOC001"):
        ex = NoCExecutor(g, ring, cfg=bad_cfg, verify="warn")
    assert "NOC001" in {d.code for d in ex.verification}
    # off: constructs silently, nothing recorded
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ex = NoCExecutor(g, ring, cfg=bad_cfg, verify="off")
    assert ex.verification == []
    # clean configs keep their (warning-only) findings on the executor
    ex = NoCExecutor(g, ring)
    assert A.errors(ex.verification) == []
    with pytest.raises(ValueError, match="verify"):
        NoCExecutor(g, ring, verify="loud")


def test_verify_executor_flags_bad_placement():
    g = _diamond()
    with pytest.raises(A.VerificationError) as ei:
        NoCExecutor(g, make_topology("mesh", 4),
                    placement={"src": 0, "l": 1, "r": 2, "join": 77})
    assert "NOC007" in {d.code for d in ei.value.diagnostics}


def test_nocconfig_rejects_bad_fields_eagerly():
    for field, value in [("flit_data_width", 0), ("flit_buffer_depth", -1),
                         ("bridge_fifo_depth", 0), ("switch_buffer_depth", 0),
                         ("switch_vcs", 0)]:
        with pytest.raises(ValueError, match="NOC012"):
            NoCConfig(**{field: value})


def test_lint_cli():
    from repro.analysis.lint import main

    assert main(["benchmarks"]) == 0
    assert main(["configs"]) == 0
    assert main(["nope"]) == 2


# ---------------------------------------------------------------------------
# traffic edge cases (core/traffic.py)
# ---------------------------------------------------------------------------

def test_traffic_config_validation():
    with pytest.raises(ValueError, match="injection_rate"):
        TrafficConfig(injection_rate=0.0)
    with pytest.raises(ValueError, match="hotspot_frac"):
        TrafficConfig(hotspot_frac=1.5)
    with pytest.raises(ValueError, match="packet_flits"):
        TrafficConfig(packet_flits=0)
    with pytest.raises(ValueError, match="burst_len"):
        TrafficConfig(burst_len=0)
    with pytest.raises(ValueError, match="n_packets"):
        TrafficConfig(n_packets=-1)
    with pytest.raises(ValueError, match="pattern"):
        TrafficConfig(pattern="tornado")


def test_traffic_single_node_topology():
    topo = make_topology("ring", 1)
    for pattern in PATTERNS:
        cfg = TrafficConfig(pattern=pattern, injection_rate=0.1)
        assert np.array_equal(traffic_matrix(topo, cfg), np.zeros((1, 1)))
        assert generate_traffic(topo, cfg) == []


@pytest.mark.parametrize("tname,n", [("ring", 8), ("mesh", 16),
                                     ("torus", 16), ("fattree", 8)])
def test_traffic_matrix_rows_conserve(tname, n):
    topo = make_topology(tname, n)
    for pattern in PATTERNS:
        for frac in (0.0, 0.3, 1.0):
            cfg = TrafficConfig(pattern=pattern, injection_rate=0.1,
                                hotspot=5, hotspot_frac=frac)
            m = traffic_matrix(topo, cfg)
            assert np.allclose(m.sum(axis=1), 1.0), (pattern, frac)
            assert np.all(np.diag(m) == 0.0), (pattern, frac)
            assert np.all(m >= 0.0), (pattern, frac)


def test_hotspot_extremes():
    topo = make_topology("mesh", 16)
    # frac=0 degenerates to uniform
    m0 = traffic_matrix(topo, TrafficConfig(pattern="hotspot",
                                            injection_rate=0.1,
                                            hotspot=5, hotspot_frac=0.0))
    uni = traffic_matrix(topo, TrafficConfig(injection_rate=0.1))
    assert np.allclose(m0, uni)
    # frac=1: every other node sends only to the hotspot; the hotspot itself
    # falls back to uniform instead of a zero row
    m1 = traffic_matrix(topo, TrafficConfig(pattern="hotspot",
                                            injection_rate=0.1,
                                            hotspot=5, hotspot_frac=1.0))
    for s in range(16):
        if s != 5:
            assert m1[s, 5] == 1.0
    assert np.allclose(m1[5], uni[5])
    # drawn packets follow: every non-hotspot source targets node 5
    pkts = generate_traffic(topo, TrafficConfig(pattern="hotspot",
                                                injection_rate=0.5,
                                                hotspot=5, hotspot_frac=1.0,
                                                n_packets=4))
    for p in pkts:
        if p.src != 5:
            assert p.dst == 5


def test_generate_traffic_counts_and_low_rate():
    topo = make_topology("mesh", 9)
    for pattern in PATTERNS:
        cfg = TrafficConfig(pattern=pattern, injection_rate=0.001,
                            n_packets=3, hotspot=2)
        pkts = generate_traffic(topo, cfg)
        assert len(pkts) == 9 * 3          # exactly n_packets per source
        assert all(p.src != p.dst for p in pkts)
        assert all(0 <= p.dst < 9 for p in pkts)
        # a near-zero rate spreads injections out but still emits them all
        # (bursty fits n_packets=3 < burst_len into one t=0 burst)
        if pattern != "bursty":
            assert max(p.t_inject for p in pkts) > 0
        cfg0 = TrafficConfig(pattern=pattern, injection_rate=0.001,
                             n_packets=0, hotspot=2)
        assert generate_traffic(topo, cfg0) == []
