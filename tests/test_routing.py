"""Routing schedules: exactly-once delivery == device transpose, both in the
numpy simulator and (subprocess, 12 fake devices) the shard_map collectives."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import make_topology, simulate_schedule
from tests.conftest import run_with_devices


@given(st.sampled_from(["ring", "mesh", "torus", "fattree"]),
       st.sampled_from([2, 4, 6, 9, 12, 16]),
       st.integers(1, 9))
@settings(max_examples=30, deadline=None)
def test_simulator_is_transpose(name, n, c):
    """Every message delivered exactly once to the right node (the property
    CONNECT's flow control guarantees; here by schedule construction)."""
    rng = np.random.default_rng(n * 100 + c)
    topo = make_topology(name, n)
    msgs = rng.integers(0, 255, size=(n, n, c), dtype=np.uint8)
    out, stats = simulate_schedule(topo, msgs)
    assert np.array_equal(out, msgs.swapaxes(0, 1))
    assert stats.rounds <= topo.a2a_rounds()


def test_round_counts_match_model():
    for name in ("ring", "mesh", "torus", "fattree"):
        topo = make_topology(name, 16)
        msgs = np.ones((16, 16, 4), np.uint8)
        _, stats = simulate_schedule(topo, msgs)
        assert stats.rounds == topo.a2a_rounds(), name


@pytest.mark.slow
def test_shard_map_schedules_match_oracle():
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core import make_topology
from repro.core.routing import all_to_all_for, topology_axes
for name in ("ring","mesh","torus","fattree"):
    for n in (4, 12):
        topo = make_topology(name, n)
        axes = topology_axes(topo)
        devs = np.array(jax.devices()[:n]).reshape([s for _, s in axes])
        mesh = Mesh(devs, [a for a, _ in axes])
        fn = all_to_all_for(topo)
        x = jnp.arange(n*n*3, dtype=jnp.float32).reshape(n, n, 3)
        in_spec = P(tuple(a for a,_ in axes)) if len(axes)>1 else P(axes[0][0])
        sm = shard_map(lambda b: fn(b.reshape(n, 3)).reshape(1, n, 3),
                       mesh=mesh, in_specs=in_spec, out_specs=in_spec,
                       check_vma=False)
        out = np.asarray(sm(x))
        assert np.array_equal(out, np.asarray(x).swapaxes(0,1)), (name, n)
print("OK")
""", n_devices=12)
