"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV rows (plus table-formatted sections).
Tables:
  table1_wrapper   — paper Tables I–III analog: PE cost without/with the NoC
                     wrapper (bytes + flit framing overhead).
  table4_bmvm_iter — paper Table IV analog: BMVM speedup vs iterations r
                     (software oracle vs kernel datapath), n=64 k=8 f=2, 4 PEs;
                     plus the NoC-sim r-sweep: compiled flit-program engine
                     (mode="sim") vs the seed per-message loop
                     (mode="sim_python"), reporting us/iter and speedup.
  table5_topology  — paper Table V analog: BMVM time vs topology
                     (ring/mesh/torus/fattree), measured round-by-round
                     schedule simulation + analytic alpha-beta model at the
                     paper's 64-PE scale.
  table5_batched   — batched flit-program engine: B input sets through one
                     (B, n, n, bytes) simulation vs B sequential sim runs.
  table6_spmd      — SPMD flit-program execution: the same compiled schedule
                     lowered onto shard_map + ppermute over an 8-device mesh
                     (mode="spmd") vs the numpy simulator (mode="sim"),
                     verifying bit-identical outputs and NoCStats; re-execs
                     itself under XLA_FLAGS when only one device is visible.
  table7_moe_noc   — MoE token dispatch over the compiled NoC route programs:
                     drops vs NoCConfig.flit_buffer_depth across all 4
                     topologies, exact flit/round/link-byte counters
                     (== 2x route_program_stats), Table-I-style wrapper
                     framing of the dispatch buffers; re-execs under
                     XLA_FLAGS when single-device.
  table8_interchip — inter-chip bridge subsystem: BMVM partitioned across pod
                     cuts over quasi-SERDES links, sweeping cut count ×
                     wire_bits × compression (multi-FPGA latency/bisection
                     trade-off), with sim/spmd/analytic parity gates and the
                     serdes-aware pod-cut co-optimizer; re-execs under
                     XLA_FLAGS when single-device.
  table9_congestion— buffered wormhole switching under load: injection rate ×
                     buffer_depth → latency/throughput saturation curves for
                     uniform / hotspot / transpose / bursty traffic on the
                     16-node mesh (cycle simulator vs the analytic
                     lower-bound/saturation model, with drain + exactly-once
                     + bound gates), plus a torus depth-1 deadlock-freedom
                     gate and an executor-level buffered-vs-sim parity row.
  table11_observability — telemetry subsystem gates: trace↔NoCStats bit-exact
                     parity (sim + buffered), zero events allocated with
                     tracing off plus the on/off overhead ratio, and the
                     committed sample Perfetto trace re-validated against the
                     Chrome trace-event schema.
  placement_search — annealing optimize_placement vs round-robin/greedy:
                     Σ traffic×hops cost (and cross-pod cut bytes) for the
                     LDPC / BMVM / particle-filter graphs.
  fig_ldpc         — LDPC decoder throughput (vectorized+kernel) + NoC stats.
  fig_pf           — particle-filter tracking throughput + accuracy.
  lm_step          — LM-stack microbench: smoke-arch train-step wall time.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

import jax
import jax.numpy as jnp


def _timeit(fn, n=5, warmup=2):
    for _ in range(warmup):
        fn()
    t0 = time.monotonic()
    for _ in range(n):
        fn()
    return (time.monotonic() - t0) / n * 1e6  # us


def _reexec_with_devices(table: str, fast: bool, child_env: str, n_dev: int = 8):
    """Multi-device sections re-exec themselves with fake CPU devices when run
    single-device (the smoke/bench environment pins jax to one visible
    device).  Returns the child's rows, or None when enough devices are
    already visible.  One re-exec only: if forcing host devices had no effect
    (e.g. jax picked a non-CPU backend) the child guard fails fast instead of
    recursing, and failures raise so the CI gate goes red."""
    import os

    if jax.device_count() >= n_dev:
        return None
    if os.environ.get(child_env):
        raise RuntimeError(
            f"{table}: only {jax.device_count()} device(s) despite "
            f"--xla_force_host_platform_device_count={n_dev}")
    import subprocess
    import sys

    env = dict(os.environ)
    flag = f"--xla_force_host_platform_device_count={n_dev}"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + flag).strip()
    env[child_env] = "1"
    cmd = [sys.executable, "-m", "benchmarks.run", "--only", table]
    if fast:
        cmd.append("--fast")
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=600)
    if out.returncode != 0:
        raise RuntimeError(
            f"{table} subprocess failed:\n"
            + "\n".join((out.stderr or out.stdout).strip().splitlines()[-10:]))
    prefix = table.split("_")[0] + "_"
    return [ln for ln in out.stdout.splitlines() if ln.startswith(prefix)]


def table1_wrapper(fast: bool) -> list[str]:
    from repro.apps.ldpc import build_ldpc_graph, fano_plane_H
    from repro.apps.particle_filter import PFConfig, build_pf_graph
    from repro.core import NoCConfig, wrapper_overhead

    rows = []
    g, _ = build_ldpc_graph(fano_plane_H())
    for r in wrapper_overhead(g, NoCConfig())[:2]:
        rows.append(f"table1_ldpc_{r['pe']},0,"
                    f"wo={r['wo_wrapper_bytes']}B with={r['with_wrapper_bytes']}B "
                    f"overhead={r['overhead']}")
    gpf = build_pf_graph(PFConfig(n_particles=64), 4)
    for r in wrapper_overhead(gpf, NoCConfig())[:2]:
        rows.append(f"table3_pf_{r['pe']},0,"
                    f"wo={r['wo_wrapper_bytes']}B with={r['with_wrapper_bytes']}B "
                    f"overhead={r['overhead']}")
    return rows


def table4_bmvm_iter(fast: bool) -> list[str]:
    from repro.apps import bmvm

    rng = np.random.default_rng(0)
    cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)
    A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
    V = rng.integers(0, 2, (4, 64)).astype(np.uint8)   # 4 "PEs"/threads analog
    lut = bmvm.preprocess(A, cfg)
    Vj = jnp.asarray(V)
    # correctness of the Pallas kernel datapath (interpret mode = validation;
    # its wall time is meaningless on CPU, so the timed "hardware" path is the
    # XLA-jitted LUT datapath that the kernel implements)
    assert np.array_equal(np.asarray(bmvm.iterate_kernel(lut, Vj, cfg, 3)),
                          bmvm.software_ref(A, V, 3))
    rows = []
    iters = [1, 10, 100] if fast else [1, 10, 100, 1000]
    for r in iters:
        t_sw = _timeit(lambda: bmvm.software_ref(A, V, r), n=3)
        it = jax.jit(lambda v: bmvm.iterate_kernel(lut, v, cfg, r, use_kernel=False))
        it(Vj)  # compile
        t_hw = _timeit(lambda: jax.block_until_ready(it(Vj)), n=3)
        rows.append(f"table4_bmvm_r{r},{t_hw:.1f},speedup_vs_sw={t_sw / t_hw:.2f}")
    # NoC-sim engine r-sweep: compiled flit program vs the seed per-message loop
    from repro.core import NoCExecutor, make_topology
    from repro.kernels import ref as kref

    v1 = V[0]
    g, feedback = bmvm.build_bmvm_graph(np.asarray(lut), cfg)
    ex = NoCExecutor(g, make_topology(cfg.topology, 2 * cfg.n_pe))
    vw = np.asarray(kref.gf2_pack_vector(jnp.asarray(v1), cfg.k), np.uint32)
    f = cfg.fold
    inputs = {f"lut{i}.v": vw[i * f:(i + 1) * f] for i in range(cfg.n_pe)}
    ex.run_iterative(inputs, feedback, 2, mode="sim")         # jit warmup
    ex.run_iterative(inputs, feedback, 2, mode="sim_python")  # fair warmup
    for r in ([1, 10] if fast else [1, 10, 100]):
        t_leg = _timeit(lambda: ex.run_iterative(inputs, feedback, r, mode="sim_python"),
                        n=1, warmup=0) / r
        t_sim = _timeit(lambda: ex.run_iterative(inputs, feedback, r, mode="sim"),
                        n=1, warmup=0) / r
        out_s, _ = ex.run_iterative(inputs, feedback, r, mode="sim")
        out_l, _ = ex.run_iterative(inputs, feedback, r, mode="sim_python")
        assert all(np.array_equal(out_s[k], out_l[k]) for k in out_s)
        rows.append(f"table4_simengine_r{r},{t_sim:.1f},"
                    f"seed_loop_us={t_leg:.1f} speedup_vs_seed_loop={t_leg / t_sim:.2f}")
    return rows


def table5_topology(fast: bool) -> list[str]:
    from repro.apps import bmvm
    from repro.core import compare

    n, k, f = (256, 4, 4) if fast else (1024, 4, 4)    # paper: n=1024 k=4 f=4
    rng = np.random.default_rng(1)
    cfg = bmvm.BMVMConfig(n=n, k=k, fold=f)
    A = rng.integers(0, 2, (n, n)).astype(np.uint8)
    v = rng.integers(0, 2, (n,)).astype(np.uint8)
    lut = np.asarray(bmvm.preprocess(A, cfg))
    rows = []
    r = 2
    sw = bmvm.software_ref(A, v[None], r)
    for topo in ("ring", "mesh", "torus", "fattree"):
        t0 = time.monotonic()
        out, stats = bmvm.iterate_noc_sim(jnp.asarray(lut), v, cfg, r, topology=topo)
        dt = (time.monotonic() - t0) * 1e6
        assert np.array_equal(out.reshape(1, -1), sw), topo
        rows.append(f"table5_bmvm_{topo},{dt:.0f},"
                    f"rounds={stats.rounds} link_bytes={stats.link_bytes} "
                    f"flits={stats.flits}")
    # analytic alpha-beta model at the paper's 64-PE scale
    for row in compare(64, chunk_bytes=2 * (n // k // f)):
        rows.append(f"table5_model_{row['topology']},{row['model_time_us']:.2f},"
                    f"rounds={row['rounds']} avg_hops={row['avg_hops']}")
    return rows


def table5_batched(fast: bool) -> list[str]:
    """Batched engine: B input sets through one (B, n, n, bytes) simulation."""
    from repro.apps import bmvm
    from repro.core import NoCExecutor, make_topology
    from repro.kernels import ref as kref

    rng = np.random.default_rng(5)
    cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)
    A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
    lut = np.asarray(bmvm.preprocess(A, cfg))
    g, _ = bmvm.build_bmvm_graph(lut, cfg)
    B = 8 if fast else 32
    V = rng.integers(0, 2, (B, 64)).astype(np.uint8)
    vw = np.asarray(kref.gf2_pack_vector(jnp.asarray(V), cfg.k), np.uint32)  # (B, C)
    f = cfg.fold
    rows = []
    for topo in ("ring", "mesh", "torus", "fattree"):
        ex = NoCExecutor(g, make_topology(topo, 2 * cfg.n_pe))
        binp = {f"lut{i}.v": vw[:, i * f:(i + 1) * f] for i in range(cfg.n_pe)}
        sinp = [{f"lut{i}.v": vw[b, i * f:(i + 1) * f] for i in range(cfg.n_pe)}
                for b in range(B)]
        ex.run_batch(binp)                 # vmap/jit warmup
        [ex.run(s) for s in sinp[:1]]
        t_b = _timeit(lambda: ex.run_batch(binp), n=2, warmup=0)
        t_s = _timeit(lambda: [ex.run(s) for s in sinp], n=2, warmup=0)
        bouts, bstats = ex.run_batch(binp)
        souts = [ex.run(s)[0] for s in sinp]
        assert all(np.array_equal(bouts[k][b], souts[b][k])
                   for b in range(B) for k in bouts)
        rows.append(f"table5_batched_{topo},{t_b:.0f},B={B} seq_us={t_s:.0f} "
                    f"speedup={t_s / t_b:.2f} rounds={bstats.rounds}")
    return rows


def table6_spmd(fast: bool) -> list[str]:
    """SPMD (shard_map + ppermute) vs numpy-sim execution of one flit program.

    The smoke/bench environment pins jax to one visible device, so when run
    single-device this section re-execs itself in a subprocess with 8 fake CPU
    devices and forwards the child's rows."""
    n_dev = 8
    child = _reexec_with_devices("table6_spmd", fast, "_TABLE6_SPMD_CHILD", n_dev)
    if child is not None:
        return child

    from repro.apps import bmvm
    from repro.core import NoCExecutor, make_topology
    from repro.kernels import ref as kref

    rng = np.random.default_rng(7)
    cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)           # 4 PEs on 8 nodes
    A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
    v = rng.integers(0, 2, (64,)).astype(np.uint8)
    lut = np.asarray(bmvm.preprocess(A, cfg))
    g, feedback = bmvm.build_bmvm_graph(lut, cfg)
    vw = np.asarray(kref.gf2_pack_vector(jnp.asarray(v), cfg.k), np.uint32)
    f = cfg.fold
    inputs = {f"lut{i}.v": vw[i * f:(i + 1) * f] for i in range(cfg.n_pe)}
    r = 2 if fast else 5
    rows = []
    for topo in ("ring", "mesh", "torus", "fattree"):
        ex = NoCExecutor(g, make_topology(topo, 2 * cfg.n_pe))
        ex.run_iterative(inputs, feedback, 1, mode="sim")    # jit warmup
        ex.run_iterative(inputs, feedback, 1, mode="spmd")   # trace/compile
        res = {}
        t_sim = _timeit(lambda: res.__setitem__(
            "sim", ex.run_iterative(inputs, feedback, r, mode="sim")),
            n=2, warmup=0) / r
        t_spmd = _timeit(lambda: res.__setitem__(
            "spmd", ex.run_iterative(inputs, feedback, r, mode="spmd")),
            n=2, warmup=0) / r
        (out_sim, st_sim), (out_spmd, st_spmd) = res["sim"], res["spmd"]
        assert all(np.array_equal(out_sim[k], out_spmd[k]) for k in out_sim), topo
        assert st_sim.as_dict() == st_spmd.as_dict(), topo
        rows.append(f"table6_spmd_{topo},{t_spmd:.0f},sim_us={t_sim:.0f} "
                    f"spmd_vs_sim={t_sim / max(t_spmd, 1e-9):.2f}x "
                    f"rounds={st_spmd.rounds} stats_identical=True")
    return rows


def table7_moe_noc(fast: bool) -> list[str]:
    """MoE token dispatch over the compiled NoC route programs: the
    drops-vs-`flit_buffer_depth` curve, Table-I wrapper framing applied to the
    dispatch buffers, and exact flit/round/link-byte counters.

    Gates (CI goes red on stats drift):
      * rounds/link_bytes == 2x `route_program_stats` of the dispatched cube,
      * drops identical across all 4 topologies (capacity is routing-blind),
      * drops == the gather engine's (unified capacity semantics),
      * drops monotone nonincreasing in buffer depth, 0 once cf_eff >= top_k.
    Re-execs itself with 8 fake CPU devices when run single-device."""
    n_dev = 8
    child = _reexec_with_devices("table7_moe_noc", fast, "_TABLE7_MOE_CHILD", n_dev)
    if child is not None:
        return child

    from jax.sharding import Mesh

    from repro.core.noc import NoCConfig
    from repro.core.routing import compile_routes, route_program_stats
    from repro.core.topology import make_topology
    from repro.launch.mesh import set_mesh
    from repro.models import moe as M
    from repro.models.layers import init_params

    mesh = Mesh(np.array(jax.devices()).reshape(1, n_dev), ("data", "model"))
    rng = np.random.default_rng(7)
    E, d, k = 16, 64, 2
    B, S = 2, 64
    base = M.MoEConfig(d_model=d, n_experts=E, top_k=k, d_ff=96, impl="dense")
    params = init_params(M.moe_specs(base), jax.random.key(0))
    x = jnp.asarray(rng.normal(size=(B, S, d)), jnp.float32)
    depths = [1, 2, 4, 8] if fast else [1, 2, 4, 8, 16]
    topos = ("fattree", "ring", "mesh2d", "torus2d")
    rows = []

    def jit_moe(c):
        """jit one config; capture the static half of MoEDispatchStats at
        trace time (drops/peak flow out as traced outputs)."""
        holder = {}

        def f(p, xx):
            out, _, st = M.moe_apply(p, xx, c)
            holder["st"] = st
            return out, st.drops, st.peak_occupancy

        return jax.jit(f), holder

    with set_mesh(mesh):
        ref, _, _ = M.moe_apply(params, x, base)
        prev_drops = None
        for depth in depths:
            ncfg = NoCConfig(flit_buffer_depth=depth)
            gf, _ = jit_moe(M.MoEConfig(d, E, k, 96, impl="gather", noc=ncfg))
            g_drops = int(gf(params, x)[1])
            drops_at_depth = []
            for topo in topos:
                c = M.MoEConfig(d, E, k, 96, impl="noc", noc_topology=topo,
                                noc=ncfg)
                nf, holder = jit_moe(c)
                out, drops, peak = jax.block_until_ready(nf(params, x))
                t = _timeit(lambda: jax.block_until_ready(nf(params, x)[0]),
                            n=2, warmup=0)
                st = holder["st"]
                # exact-counter gate: 2x route_program_stats of the cube
                prog = compile_routes(make_topology(topo, n_dev))
                msg = (E // n_dev) * st.capacity * d * 4
                ss = route_program_stats(prog, n_dev * n_dev * msg)
                assert st.rounds == 2 * ss.rounds, topo
                assert st.link_bytes == 2 * ss.link_bytes, topo
                assert st.flits == 2 * n_dev * n_dev * ncfg.flits_for(msg), topo
                drops_at_depth.append(int(drops))
                # Table-I wrapper framing of one (src, dst-rank) buffer
                raw = msg
                flit_b = ncfg.flits_for(msg) * ncfg.flit_wire_bytes
                fifo_b = depth * ncfg.flits_for(d * 4) * ncfg.flit_wire_bytes
                rows.append(
                    f"table7_moe_noc_{topo}_d{depth},{t:.0f},"
                    f"drops={int(drops)} peak={int(peak)} "
                    f"cap={st.capacity} cf_eff={st.capacity_factor:.3f} "
                    f"flits={st.flits} rounds={st.rounds} "
                    f"link_bytes={st.link_bytes} "
                    f"wrapper_overhead={round((flit_b + fifo_b - raw) / raw, 3)}")
            # capacity is routing-blind: all topologies drop identically,
            # and the gather engine (unified semantics) agrees
            assert len(set(drops_at_depth)) == 1, drops_at_depth
            assert drops_at_depth[0] == g_drops, (drops_at_depth, g_drops)
            if prev_drops is not None:
                assert drops_at_depth[0] <= prev_drops, "drops not monotone"
            prev_drops = drops_at_depth[0]
        # deep enough buffers => drop-free => exact match with the oracle
        nf, _ = jit_moe(M.MoEConfig(d, E, k, 96, impl="noc",
                                    noc_topology="torus2d",
                                    noc=NoCConfig(flit_buffer_depth=B * S * k)))
        out, drops, _ = nf(params, x)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert int(drops) == 0
        assert err < 1e-4
        rows.append(f"table7_moe_noc_dropfree,0,depth={B * S * k} drops=0 "
                    f"max_err_vs_dense={err:.2e}")
    return rows


def table8_interchip(fast: bool) -> list[str]:
    """Inter-chip bridge subsystem (paper §III, Fig. 6): the BMVM NoC
    partitioned across pod cuts over quasi-SERDES links, sweeping cut count ×
    wire_bits × compression — the multi-FPGA latency/bisection trade-off.

    Gates (CI goes red on drift):
      * partitioned sim outputs bit-identical to the unpartitioned run, and
        all non-bridge NoCStats fields identical;
      * `bridge_program_stats` exactly equals the simulator's BridgeStats;
      * partitioned spmd == partitioned sim in outputs *and* NoCStats
        (bridge counters included) on the (pod, node) device mesh.
    Effective latency = rounds + bridge stall rounds (serialization back-
    pressure); `cut_wire_bytes` is the message-level serdes framing incl.
    compression (the co-optimizer's objective term), while `bridge_wire_*`
    count the lossless flit tunnel.  Re-execs itself with 8 fake CPU devices
    when run single-device."""
    n_dev = 8
    child = _reexec_with_devices("table8_interchip", fast, "_TABLE8_ICHIP_CHILD",
                                 n_dev)
    if child is not None:
        return child

    from repro.apps import bmvm
    from repro.core import (NoCConfig, bridge_program_stats, compile_bridges,
                            compile_routes, cut, make_topology, optimize_pod_cut,
                            place_round_robin, placement_cost,
                            simulate_bridged_program)
    from repro.core.interchip import BridgeConfig
    from repro.core.serdes import QuasiSerdesConfig

    rng = np.random.default_rng(8)
    cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)           # 4 PEs on 8 NoC nodes
    A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
    v = rng.integers(0, 2, (64,)).astype(np.uint8)
    lut = np.asarray(bmvm.preprocess(A, cfg))
    g, _ = bmvm.build_bmvm_graph(lut, cfg)
    sw = bmvm.software_ref(A, v[None], 2)
    topo = make_topology("mesh", 8)
    cuts = {2: [0] * 4 + [1] * 4, 4: [0, 0, 1, 1, 2, 2, 3, 3]}
    wire_sweep = (8, 16) if fast else (8, 16, 32)
    comp_sweep = ("none", "bf16")
    rows = []
    out_ref, st_ref = bmvm.iterate_noc_sim(jnp.asarray(lut), v, cfg, 2,
                                           topology="mesh")
    for n_pods, pods in cuts.items():
        for wb in wire_sweep:
            for comp in comp_sweep:
                scfg = QuasiSerdesConfig(wire_bits=wb, lanes=2, compress=comp)
                t0 = time.monotonic()
                out, st = bmvm.iterate_noc_sim(jnp.asarray(lut), v, cfg, 2,
                                               topology="mesh", pods=pods,
                                               serdes_cfg=scfg)
                dt = (time.monotonic() - t0) * 1e6
                # gate 1: the cut is semantically transparent — identical to
                # the unpartitioned run AND to the software oracle
                assert np.array_equal(out, out_ref), (n_pods, wb, comp)
                assert np.array_equal(out.reshape(1, -1), sw), (n_pods, wb, comp)
                d_ref, d = st_ref.as_dict(), st.as_dict()
                for k in d_ref:
                    if not (k.startswith("bridge_") or k.startswith("cross_pod_")):
                        assert d_ref[k] == d[k], (n_pods, wb, comp, k)
                # gate 2: analytic bridge stats == simulated, on a raw cube
                plan = cut(g, place_round_robin(g, topo), pods, scfg)
                bprog = compile_bridges(compile_routes(topo), plan,
                                        BridgeConfig(serdes=scfg, fifo_depth=8))
                cube = rng.integers(0, 255, (8, 8, 16), dtype=np.uint8)
                _, _, b_sim = simulate_bridged_program(bprog, cube)
                b_ana = bridge_program_stats(bprog, cube.nbytes)
                assert b_ana.as_dict() == b_sim.as_dict(), (n_pods, wb, comp)
                msg_wire = plan.wire_bytes(g)
                rows.append(
                    f"table8_interchip_p{n_pods}_w{wb}_{comp},{dt:.0f},"
                    f"latency_rounds={st.rounds + st.bridge_stall_rounds} "
                    f"stall_rounds={st.bridge_stall_rounds} "
                    f"bridge_beats={st.bridge_beats} "
                    f"bridge_wire_bytes={st.bridge_wire_bytes} "
                    f"peak_fifo={st.bridge_peak_fifo} "
                    f"bridges={b_sim.n_bridges} cut_wire_bytes={msg_wire}")
    # gate 3: spmd differential on the (pod, node) mesh, 2- and 4-pod cuts
    for n_pods, pods in cuts.items():
        out_sim, st_sim = bmvm.iterate_noc_sim(jnp.asarray(lut), v, cfg, 2,
                                               topology="mesh", pods=pods)
        out_spmd, st_spmd = bmvm.iterate_noc_sim(jnp.asarray(lut), v, cfg, 2,
                                                 topology="mesh", pods=pods,
                                                 mode="spmd")
        assert np.array_equal(out_spmd, out_sim), n_pods
        assert st_spmd.as_dict() == st_sim.as_dict(), n_pods
        rows.append(f"table8_interchip_spmd_p{n_pods},0,"
                    f"stats_identical=True "
                    f"bridge_beats={st_spmd.bridge_beats} "
                    f"stall_rounds={st_spmd.bridge_stall_rounds}")
    # co-optimizer: pod cut × serdes settings under the shared objective
    grid = [QuasiSerdesConfig(wire_bits=wb, lanes=ln, compress=cp)
            for wb in wire_sweep for ln in (1, 8) for cp in comp_sweep]
    plan, cost = optimize_pod_cut(g, topo, n_pods=2, serdes_grid=grid,
                                  iters=300 if fast else 1500, seed=0)
    naive = placement_cost(g, topo, place_round_robin(g, topo),
                           [0] * 4 + [1] * 4, QuasiSerdesConfig())
    rows.append(f"table8_coopt,0,cost={cost:.0f} naive={naive:.0f} "
                f"wire_bits={plan.serdes_cfg.wire_bits} "
                f"lanes={plan.serdes_cfg.lanes} "
                f"compress={plan.serdes_cfg.compress} "
                f"cut_beats={plan.wire_beats(g)}")
    assert cost <= naive
    return rows


def table9_congestion(fast: bool) -> list[str]:
    """Buffered wormhole switching saturation curves (mode="buffered" stack).

    Sweeps offered load (as a fraction of the analytic saturation rate) ×
    input-FIFO depth for the four traffic patterns on the 16-node mesh.
    Gates (CI goes red on regression):
      * drain + exactly-once: every offered packet is delivered, at every
        depth including the depth=1 worst case;
      * sim/analytic agreement: cycles >= `switch_lower_bound` and accepted
        throughput <= `saturation_rate`, for every cell of the sweep;
      * deadlock freedom on wrapped topologies: a torus depth=1 hotspot mix
        (the adversarial configuration for wormhole deadlock) must drain;
      * executor parity: `mode="buffered"` delivers LDPC payloads identical
        to `mode="sim"`.
    Latency is reported in cycles (avg and max); throughput in
    flits/cycle/node against the saturation rate."""
    from repro.core.switch import (SwitchConfig, saturation_rate,
                                   simulate_switch, switch_lower_bound)
    from repro.core.topology import make_topology
    from repro.core.traffic import (TrafficConfig, generate_traffic,
                                    traffic_matrix)

    topo = make_topology("mesh", 16)
    n_pk = 16 if fast else 48
    depths = (1, 4) if fast else (1, 2, 4, 8)
    load_fracs = (0.3, 1.5) if fast else (0.2, 0.5, 0.8, 1.2, 2.0)
    rows = []
    for pattern in ("uniform", "hotspot", "transpose", "bursty"):
        tm = traffic_matrix(topo, TrafficConfig(pattern=pattern, hotspot=5))
        sat = saturation_rate(topo, tm)
        for depth in depths:
            for frac in load_fracs:
                tcfg = TrafficConfig(pattern=pattern, hotspot=5,
                                     injection_rate=frac * sat,
                                     n_packets=n_pk, seed=0)
                pkts = generate_traffic(topo, tcfg)
                t0 = time.monotonic()
                res = simulate_switch(topo, pkts,
                                      SwitchConfig(buffer_depth=depth))
                dt = (time.monotonic() - t0) * 1e6
                st = res.stats
                # gates: drain/exactly-once + analytic agreement
                assert st.packets == len(pkts), (pattern, depth, frac)
                assert st.cycles >= switch_lower_bound(topo, pkts), \
                    (pattern, depth, frac)
                thr = st.throughput(topo.n_nodes)
                assert thr <= sat + 1e-9, (pattern, depth, frac)
                rows.append(
                    f"table9_{pattern}_d{depth}_l{frac},{dt:.0f},"
                    f"offered={frac * sat:.3f} accepted={thr:.3f} "
                    f"sat_rate={sat:.3f} cycles={st.cycles} "
                    f"avg_lat={st.avg_latency:.1f} max_lat={st.latency_max} "
                    f"stalls={st.stall_cycles} arb_losses={st.arb_losses} "
                    f"max_queue={st.max_queue}")
    # deadlock-freedom gate: torus at depth=1 under a hotspot mix is the
    # adversarial wormhole configuration; dateline VCs must keep it live
    torus = make_topology("torus", 16)
    pkts = generate_traffic(torus, TrafficConfig(
        pattern="hotspot", hotspot=5, hotspot_frac=0.7,
        injection_rate=0.8, n_packets=n_pk, seed=7))
    res = simulate_switch(torus, pkts, SwitchConfig(buffer_depth=1))
    assert res.stats.packets == len(pkts), "torus depth-1 failed to drain"
    rows.append(f"table9_torus_depth1_gate,0,packets={res.stats.packets} "
                f"cycles={res.stats.cycles} deadlock_free=True")
    # executor parity gate: buffered == sim on a real app
    from repro.apps import ldpc

    rng = np.random.default_rng(0)
    llr = ldpc.awgn_llr(np.zeros(7, np.int8), 3.0, rng)
    b_s, i_s, st_s = ldpc.decode_on_noc(ldpc.fano_plane_H(), llr, 10)
    t0 = time.monotonic()
    b_b, i_b, st_b = ldpc.decode_on_noc(ldpc.fano_plane_H(), llr, 10,
                                        mode="buffered")
    dt = (time.monotonic() - t0) * 1e6
    assert np.array_equal(b_s, b_b) and np.array_equal(i_s, i_b)
    assert st_b.payload_bytes == st_s.payload_bytes
    rows.append(f"table9_ldpc_buffered,{dt:.0f},"
                f"cycles={st_b.switch_cycles} sim_rounds={st_s.rounds} "
                f"stalls={st_b.switch_stall_cycles} "
                f"arb_losses={st_b.switch_arb_losses} outputs_identical=True")
    return rows


def table10_verify(fast: bool) -> list[str]:
    """Static verifier vs simulate-to-detect on deadlock-prone configs.

    Each cell is one (topology, n_vcs) combination at depth-1 buffers (the
    adversarial wormhole configuration) under a shift-permutation workload
    that piles every node's packets up at once.  The channel-dependency
    verifier (`repro.analysis.cdg`) gives its verdict in microseconds without
    moving a flit; the simulator (``verify=False``) either drains or wedges
    into `DeadlockError`.  Gates (CI goes red on violation):
      * soundness — every config the simulator deadlocks on was flagged
        cyclic by the verifier (no false negatives on real deadlocks);
      * no false alarms on the safe set — every verifier-safe config drains
        to completion, including the 1-VC combos the old hand guard
        rejected (2-node ring, 2x2 torus);
      * the unsafe set is non-vacuous — at least one config actually
        deadlocks in simulation."""
    from repro.analysis.cdg import deadlock_cycle
    from repro.core.switch import (DeadlockError, Packet, SwitchConfig,
                                   simulate_switch)
    from repro.core.topology import make_topology

    combos = [
        ("ring2_vc1", "ring", 2, 1),       # provably safe at 1 VC
        ("torus4_vc1", "torus", 4, 1),     # 2x2 torus: safe at 1 VC
        ("mesh16_vc1", "mesh", 16, 1),
        ("ring8_vc1", "ring", 8, 1),       # cyclic: the classic wedge
        ("ring8_vc2", "ring", 8, 2),
        ("torus16_vc1", "torus", 16, 1),   # cyclic
        ("torus16_vc2", "torus", 16, 2),
        ("fattree8_vc1", "fattree", 8, 1),
    ]
    if fast:
        combos = [c for c in combos
                  if c[0] in ("ring2_vc1", "ring8_vc1", "ring8_vc2",
                              "torus16_vc1", "mesh16_vc1")]
    rows = []
    n_deadlocked = 0
    for name, tname, n, vcs in combos:
        topo = make_topology(tname, n)
        # shift permutation, everything injected at t=0: maximal pressure
        pkts = [Packet(s, (s + max(1, n // 2)) % n, 4, t_inject=0)
                for s in range(n) for _ in range(4)]
        deadlock_cycle.cache_clear()
        t0 = time.monotonic()
        cyc = deadlock_cycle(topo, vcs)
        t_verify = (time.monotonic() - t0) * 1e6
        scfg = SwitchConfig(buffer_depth=1, n_vcs=vcs, max_cycles=20_000)
        t0 = time.monotonic()
        try:
            res = simulate_switch(topo, pkts, scfg, verify=False)
            sim = "drained"
            assert res.stats.packets == len(pkts), name
        except DeadlockError:
            sim = "deadlocked"
            n_deadlocked += 1
        t_sim = (time.monotonic() - t0) * 1e6
        verdict = "cyclic" if cyc else "safe"
        # soundness: a simulated deadlock the verifier passed is a miss
        assert not (sim == "deadlocked" and cyc is None), name
        # no false alarms: verifier-safe must drain
        assert not (cyc is None and sim != "drained"), name
        rows.append(f"table10_{name},{t_verify:.0f},verdict={verdict} "
                    f"sim={sim} sim_us={t_sim:.0f} "
                    f"speedup={t_sim / max(t_verify, 1):.0f}x "
                    f"cycle_len={len(cyc) if cyc else 0}")
    assert n_deadlocked >= 1, "unsafe set never deadlocked: gate is vacuous"
    return rows


def table11_observability(fast: bool) -> list[str]:
    """Telemetry subsystem gates (CI goes red on violation):

      * parity — aggregating a full trace (`telemetry.trace_stats`) of a
        BMVM run reproduces the engine's NoCStats bit-exactly, for both the
        schedule simulator and the cycle-accurate buffered switch;
      * zero overhead off — running untraced allocates zero TraceEvents, and
        the traced/untraced wall-clock ratio is reported;
      * schema — a freshly exported trace validates against the Chrome
        trace-event schema, and the committed sample
        ``benchmarks/SAMPLE_trace_perfetto.json`` (written on first run)
        keeps validating, so the on-disk format can't drift silently."""
    import json
    import os

    from repro.apps import bmvm
    from repro.core import NoCExecutor, make_topology
    from repro.kernels import ref as kref
    from repro.telemetry import (Tracer, chrome_trace, events_allocated,
                                 trace_stats, validate_chrome_trace)

    rng = np.random.default_rng(11)
    cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)
    A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
    v = rng.integers(0, 2, (64,)).astype(np.uint8)
    lut = np.asarray(bmvm.preprocess(A, cfg))
    g, feedback = bmvm.build_bmvm_graph(lut, cfg)
    vw = np.asarray(kref.gf2_pack_vector(jnp.asarray(v), cfg.k), np.uint32)
    f = cfg.fold
    inputs = {f"lut{i}.v": vw[i * f:(i + 1) * f] for i in range(cfg.n_pe)}
    topo = make_topology("mesh", 2 * cfg.n_pe)
    r = 2 if fast else 5
    rows = []
    # gate 1: trace -> NoCStats parity, schedule sim + buffered switch
    for mode in ("sim", "buffered"):
        tr = Tracer()
        ex = NoCExecutor(g, topo, trace=tr)
        _, st = ex.run_iterative(inputs, feedback, r, mode=mode)
        agg = trace_stats(tr)
        assert agg.as_dict() == st.as_dict(), (mode, agg.as_dict(), st.as_dict())
        rows.append(f"table11_parity_{mode},0,events={len(tr)} "
                    f"rounds={st.rounds} bit_exact=True")
    # gate 2: tracing off allocates nothing; report the on/off overhead
    ex_off = NoCExecutor(g, topo)
    ex_off.run_iterative(inputs, feedback, 1, mode="sim")   # jit warmup
    before = events_allocated()
    t_off = _timeit(lambda: ex_off.run_iterative(inputs, feedback, r,
                                                 mode="sim"), n=3, warmup=1)
    assert events_allocated() == before, "untraced run allocated TraceEvents"
    ex_on = NoCExecutor(g, topo, trace=True)
    ex_on.run_iterative(inputs, feedback, 1, mode="sim")
    t_on = _timeit(lambda: ex_on.run_iterative(inputs, feedback, r,
                                               mode="sim"), n=3, warmup=1)
    rows.append(f"table11_overhead,{t_on:.0f},untraced_us={t_off:.0f} "
                f"traced_over_untraced={t_on / max(t_off, 1e-9):.3f}")
    # gate 3: exported trace validates; the committed sample keeps validating
    tr = Tracer()
    ex = NoCExecutor(g, topo, trace=tr)
    ex.run_iterative(inputs, feedback, 2, mode="sim")
    doc = chrome_trace(tr)
    n_ev = validate_chrome_trace(doc)
    sample = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "SAMPLE_trace_perfetto.json")
    if not os.path.exists(sample):
        with open(sample, "w") as fh:
            json.dump(doc, fh, indent=1)
            fh.write("\n")
    n_sample = validate_chrome_trace(json.load(open(sample)))
    rows.append(f"table11_schema,0,fresh_events={n_ev} "
                f"sample_events={n_sample} valid=True")
    return rows


def table12_profile(fast: bool) -> list[str]:
    """Latency-profiler gates (`repro.telemetry.profile` + `regress`):

      * decomposition — per-packet/per-message components sum bit-exactly
        to measured inject→eject latency across sim / buffered / bridged
        BMVM runs, and the critical-path length equals the final logical
        clock (the per-flow p50/p99 and above-bound gap are committed as
        deterministic counters in ``BENCH_table12.json``);
      * identity — an uncontended single packet meets
        ``latency == critical path == switch_lower_bound`` exactly;
      * zero overhead — an unprofiled run allocates no LatencyRecords (and
        still no TraceEvents), extending the `events_allocated` gate;
      * regress self-test — `telemetry.regress.compare_rows` passes on
        identical rows and trips (named metric) on an injected slowdown
        (``switch_buffer_depth=1`` vs the default 4)."""
    from repro.apps import bmvm
    from repro.core import NoCConfig, NoCExecutor, cut, make_topology
    from repro.core.partition import resolve_placement
    from repro.core.switch import (Packet, SwitchConfig, simulate_switch,
                                   switch_lower_bound)
    from repro.kernels import ref as kref
    from repro.telemetry import (Tracer, events_allocated, profile_trace,
                                 records_allocated)
    from repro.telemetry.regress import compare_rows

    rng = np.random.default_rng(12)
    cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)
    A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
    v = rng.integers(0, 2, (64,)).astype(np.uint8)
    lut = np.asarray(bmvm.preprocess(A, cfg))
    g, feedback = bmvm.build_bmvm_graph(lut, cfg)
    vw = np.asarray(kref.gf2_pack_vector(jnp.asarray(v), cfg.k), np.uint32)
    f = cfg.fold
    inputs = {f"lut{i}.v": vw[i * f:(i + 1) * f] for i in range(cfg.n_pe)}
    n = 2 * cfg.n_pe
    topo = make_topology("mesh", n)
    r = 2 if fast else 5
    rows = []

    def run_profiled(mode, pods=None, noc_cfg=None):
        tr = Tracer()
        plan = None
        place = None
        if pods is not None:
            place = resolve_placement(g, topo, pod_of_node=pods)
            plan = cut(g, place, pods)
        ex = NoCExecutor(g, topo, placement=place, plan=plan, cfg=noc_cfg,
                         trace=tr)
        t0 = time.monotonic()
        ex.run_iterative(inputs, feedback, r, mode=mode)
        dt = (time.monotonic() - t0) * 1e6
        prof = profile_trace(tr).check_exact()
        cp = prof.critical_path()
        assert cp.length == tr.clock, (cp.length, tr.clock)
        return prof, cp, dt

    # gate 1: exact decomposition + critical path across the transports
    pods = [0] * (n // 2) + [1] * (n - n // 2)
    for tag, mode, p in (("sim", "sim", None), ("buffered", "buffered", None),
                         ("bridged", "sim", pods)):
        prof, cp, dt = run_profiled(mode, pods=p)
        lats = sorted(l for rec in prof.records
                      for l in [rec.latency] * rec.n)
        p50 = lats[max(0, -(-50 * len(lats) // 100) - 1)]
        p99 = lats[max(0, -(-99 * len(lats) // 100) - 1)]
        rows.append(
            f"table12_bmvm_{tag},{dt:.0f},records={sum(x.n for x in prof.records)} "
            f"waves={len(prof.waves)} p50={p50} p99={p99} "
            f"crit={cp.length} gap={cp.gap} exact=True")
    # gate 2: uncontended single packet meets the analytic bound exactly
    scfg = SwitchConfig()
    tr = Tracer()
    res = simulate_switch(topo, [Packet(0, n - 1, 4, t_inject=0)], scfg,
                          tracer=tr)
    prof = profile_trace(tr).check_exact()
    rec, cp = prof.records[0], prof.critical_path()
    bound = switch_lower_bound(topo, [Packet(0, n - 1, 4, t_inject=0)], scfg)
    assert rec.latency == cp.length == bound == res.stats.cycles, (
        rec.latency, cp.length, bound, res.stats.cycles)
    assert rec.queueing == 0 and rec.bridge == 0
    rows.append(f"table12_single_packet,0,lat={rec.latency} crit={cp.length} "
                f"bound={bound} queueing=0 identity=True")
    # gate 3: profiling off allocates nothing (records AND events)
    ex_off = NoCExecutor(g, topo)
    ex_off.run_iterative(inputs, feedback, 1, mode="buffered")
    ev0, rec0 = events_allocated(), records_allocated()
    ex_off.run_iterative(inputs, feedback, r, mode="buffered")
    assert events_allocated() == ev0, "unprofiled run allocated TraceEvents"
    assert records_allocated() == rec0, "unprofiled run allocated LatencyRecords"
    rows.append("table12_zero_overhead,0,records_delta=0 events_delta=0 "
                "gate=True")
    # gate 4: the regression diff trips on an injected slowdown and only then
    def counter_row(noc_cfg):
        tr = Tracer()
        ex = NoCExecutor(g, topo, cfg=noc_cfg, trace=tr)
        _, st = ex.run_iterative(inputs, feedback, r, mode="buffered")
        prof = profile_trace(tr).check_exact()
        return {"name": "selftest_buffered", "us": 0.0,
                "cycles": st.switch_cycles, "stalls": st.switch_stall_cycles,
                "crit": prof.critical_path().length}

    base_row = counter_row(None)
    clean = compare_rows([base_row], [counter_row(None)])
    assert not clean, f"identical runs produced findings: {clean}"
    slow = compare_rows([base_row],
                        [counter_row(NoCConfig(switch_buffer_depth=1))])
    tripped = [fi for fi in slow if fi["verdict"] == "regression"]
    assert tripped, "injected slowdown (buffer_depth=1) did not trip the gate"
    rows.append(f"table12_regress_selftest,0,clean_findings={len(clean)} "
                f"tripped=True metric={tripped[0]['metric']} "
                f"delta={tripped[0]['delta']}")
    return rows


def placement_search(fast: bool) -> list[str]:
    """Annealing placement search vs round-robin/greedy on the app graphs."""
    from repro.apps import bmvm, ldpc
    from repro.apps.particle_filter import PFConfig, build_pf_graph
    from repro.core import (cut, make_topology, optimize_placement, place_greedy,
                            place_round_robin, placement_cost)

    iters = 800 if fast else 4000
    rng = np.random.default_rng(6)
    graphs = []
    g_ldpc, _ = ldpc.build_ldpc_graph(ldpc.fano_plane_H())
    graphs.append(("ldpc_fano", g_ldpc, make_topology("mesh", 16)))
    cfg = bmvm.BMVMConfig(n=64, k=8, fold=2)
    A = rng.integers(0, 2, (64, 64)).astype(np.uint8)
    g_bmvm, _ = bmvm.build_bmvm_graph(np.asarray(bmvm.preprocess(A, cfg)), cfg)
    graphs.append(("bmvm", g_bmvm, make_topology("mesh", 2 * cfg.n_pe)))
    graphs.append(("pf", build_pf_graph(PFConfig(n_particles=64), 4),
                   make_topology("mesh", 8)))
    rows = []
    for name, g, topo in graphs:
        rr = placement_cost(g, topo, place_round_robin(g, topo))
        gr = placement_cost(g, topo, place_greedy(g, topo))
        t0 = time.monotonic()
        opt = optimize_placement(g, topo, iters=iters, seed=0)
        dt = (time.monotonic() - t0) * 1e6
        oc = placement_cost(g, topo, opt)
        rows.append(f"placement_{name},{dt:.0f},cost_rr={rr} cost_greedy={gr} "
                    f"cost_opt={oc} gain_vs_rr={rr / max(oc, 1):.2f}x")
    # cut-aware variant: 2-pod split of the LDPC mesh
    pods = [0] * 8 + [1] * 8
    topo = make_topology("mesh", 16)
    opt = optimize_placement(g_ldpc, topo, pod_of_node=pods, iters=iters, seed=0)
    cb_rr = cut(g_ldpc, place_round_robin(g_ldpc, topo), pods).cut_bytes(g_ldpc)
    cb_opt = cut(g_ldpc, opt, pods).cut_bytes(g_ldpc)
    rows.append(f"placement_ldpc_cut,0,cut_bytes_rr={cb_rr} cut_bytes_opt={cb_opt}")
    return rows


def fig_ldpc(fast: bool) -> list[str]:
    from repro.apps import ldpc

    rng = np.random.default_rng(2)
    H = ldpc.pg_ldpc_H(copies=4 if fast else 16)
    idx = ldpc.build_edge_index(H)
    B = 16
    llr = jnp.asarray(np.stack([
        ldpc.awgn_llr(np.zeros(H.shape[1], np.int8), 3.0, rng) for _ in range(B)]))
    dec = jax.jit(lambda y: ldpc.decode_minsum(idx, y, 10)[0])
    dec(llr)
    t = _timeit(lambda: jax.block_until_ready(dec(llr)), n=5)
    thpt = B * H.shape[1] / (t / 1e6)
    rows = [f"fig_ldpc_decode,{t:.1f},bits_per_s={thpt:,.0f} N={H.shape[1]} iters=10"]
    _, _, stats = ldpc.decode_on_noc(ldpc.fano_plane_H(),
                                     ldpc.awgn_llr(np.zeros(7, np.int8), 3.0, rng), 10)
    rows.append(f"fig_ldpc_noc,0,rounds={stats.rounds} flits={stats.flits} "
                f"link_bytes={stats.link_bytes}")
    return rows


def fig_pf(fast: bool) -> list[str]:
    from repro.apps import particle_filter as pf

    rng = np.random.default_rng(3)
    cfg = pf.PFConfig(img=64, roi=16, n_particles=64, n_bins=16)
    frames, truth = pf.synth_video(cfg, 6 if fast else 12, rng)
    t0 = time.monotonic()
    est = pf.track(frames, cfg)
    dt = (time.monotonic() - t0) / (frames.shape[0] - 1) * 1e6
    err = float(np.linalg.norm(est - truth, axis=1).mean())
    return [f"fig_pf_track,{dt:.0f},px_err={err:.2f} fps={1e6 / dt:.1f}"]


def lm_step(fast: bool) -> list[str]:
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh, set_mesh
    from repro.launch.steps import make_train_step
    from repro.models import transformer as T
    from repro.models.layers import init_params
    from repro.optim import AdamWConfig, adamw_init

    rows = []
    mesh = make_host_mesh()
    archs = ["llama3.2-1b", "qwen3-moe-235b-a22b"] if fast else [
        "llama3.2-1b", "qwen3-moe-235b-a22b", "jamba-v0.1-52b", "xlstm-350m"]
    rng = np.random.default_rng(4)
    for arch in archs:
        cfg = get_config(arch, smoke=True)
        params = init_params(T.abstract_params(cfg), jax.random.key(0))
        state = {"params": params, "opt": adamw_init(params)}
        B, S = 4, 64
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((B, cfg.enc_seq, cfg.d_frontend), jnp.float32)
        with set_mesh(mesh):
            step = jax.jit(make_train_step(cfg, mesh, AdamWConfig()))
            state, _ = step(state, batch)  # compile
            t = _timeit(lambda: jax.block_until_ready(step(state, batch)[1]["loss"]), n=3)
        rows.append(f"lm_train_{arch},{t:.0f},tok_per_s={B * S / (t / 1e6):,.0f}")
    return rows


TABLES = {
    "table1_wrapper": table1_wrapper,
    "table4_bmvm_iter": table4_bmvm_iter,
    "table5_topology": table5_topology,
    "table5_batched": table5_batched,
    "table6_spmd": table6_spmd,
    "table7_moe_noc": table7_moe_noc,
    "table8_interchip": table8_interchip,
    "table9_congestion": table9_congestion,
    "table10_verify": table10_verify,
    "table11_observability": table11_observability,
    "table12_profile": table12_profile,
    "placement_search": placement_search,
    "fig_ldpc": fig_ldpc,
    "fig_pf": fig_pf,
    "lm_step": lm_step,
}


# tables with committed perf-trajectory snapshots (--snapshot): future PRs
# diff BENCH_<key>.json against a fresh run to track the numbers over time
SNAPSHOTS = {
    "table4_bmvm_iter": "BENCH_table4.json",
    "table9_congestion": "BENCH_table9.json",
    "table12_profile": "BENCH_table12.json",
}


def _parse_row(row: str) -> dict:
    """One 'name,us,k=v k=v ...' CSV row -> a JSON-able dict."""
    name, us, derived = row.split(",", 2)
    parsed: dict = {"name": name, "us": float(us)}
    for tok in derived.split():
        if "=" not in tok:
            continue
        k, v = tok.split("=", 1)
        try:
            parsed[k] = int(v)
        except ValueError:
            try:
                parsed[k] = float(v)
            except ValueError:
                parsed[k] = v
    return parsed


def _snapshot_meta() -> dict:
    """Provenance stamp for a snapshot: where/what produced these numbers.

    A BENCH_*.json diff is only meaningful against its recording environment
    — the stamp makes "the numbers moved" attributable to a code change vs a
    toolchain/host change."""
    import platform
    import subprocess

    try:
        sha = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True, timeout=10,
                             cwd=os.path.dirname(os.path.abspath(__file__)),
                             ).stdout.strip() or "unknown"
    except Exception:
        sha = "unknown"
    return {
        "git_sha": sha,
        "jax": jax.__version__,
        "numpy": np.__version__,
        "python": platform.python_version(),
        "platform": platform.platform(),
        "devices": jax.device_count(),
        "backend": jax.default_backend(),
    }


def _write_snapshot(table: str, rows: list[str], fast: bool) -> str:
    """Persist a table's rows as benchmarks/BENCH_<key>.json.

    Timings (`us` and any *_us key) are environment noise, so the snapshot
    separates them from the derived counters a future PR can diff exactly;
    `meta` (git SHA, jax/numpy versions, host) records the environment the
    noise came from."""
    import json

    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        SNAPSHOTS[table])
    payload = {"table": table, "fast": fast, "meta": _snapshot_meta(),
               "rows": [_parse_row(r) for r in rows]}
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--snapshot", action="store_true",
                    help="write benchmarks/BENCH_<table>.json for tables "
                         "with a tracked perf trajectory")
    ap.add_argument("--compare", action="store_true",
                    help="instead of running tables, diff fresh runs "
                         "against the committed BENCH_*.json baselines "
                         "(delegates to repro.telemetry.regress)")
    args, extra = ap.parse_known_args()
    if args.compare:
        from repro.telemetry.regress import main as regress_main

        raise SystemExit(regress_main(extra))
    print("name,us_per_call,derived")
    for name, fn in TABLES.items():
        if args.only and args.only != name:
            continue
        t0 = time.monotonic()
        rows = fn(args.fast)
        for row in rows:
            print(row)
        if args.snapshot and name in SNAPSHOTS:
            print(f"# snapshot: {_write_snapshot(name, rows, args.fast)}")
        print(f"# {name} done in {time.monotonic() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
